"""The streaming twin service: bounded-queue ingestion onto one program.

:class:`TwinService` is the serving shell around the pure fleet core —
the role OpenDT's Kafka mesh (dc-mock -> broker -> sim-worker) plays,
collapsed onto one process and ONE compiled program:

  * **ingestion** — :meth:`TwinService.submit` pushes
    :class:`~repro.serve.producers.WindowEvent` s through a bounded queue;
    a full queue rejects (returns False) and :meth:`pump` answers by
    *rewinding* the replayable producer, so backpressure is lossless;
  * **batching** — every service step pops at most one ready window per
    resident tenant (strictly in stream order) and packs them into a
    fixed-shape :func:`~repro.core.twin.fleet_step_masked` call; whatever
    subset of lanes is ready, the program never recompiles;
  * **caching** — before dispatch each window probes the
    :class:`~repro.serve.cache.ResultCache` under its
    ``(window, stream digest, scenario digest)`` key; a hit lands the
    decoded successor state on the lane and skips the device entirely,
    bit-for-bit;
  * **pipelining** — dispatch is asynchronous (JAX's deferred execution):
    batch ``k+1`` is enqueued before batch ``k``'s outputs are pulled to
    host, so host<->device transfer overlaps compute (the double-buffer).
    Stream digests advance at *dispatch*, which is what lets consecutive
    windows of one tenant occupy consecutive in-flight batches;
  * **emission** — results are staged per tenant and released strictly in
    window order, whatever order cache hits and harvests complete in;
  * **sessions** — :meth:`checkpoint` / :meth:`restore` persist every
    tenant through :class:`~repro.serve.sessions.SessionStore`; a restored
    service + replayed producers reproduces the uninterrupted run exactly.

Time is injected (:class:`~repro.core.orchestrator.Clock`): tests drive
:meth:`run_until_idle` frozen-time, the thread-driven live mode
(:meth:`start` / :meth:`stop`) paces itself with ``clock.sleep`` only —
tracecheck TC007 keeps ambient clocks out of this module.
"""

from __future__ import annotations

import collections
import dataclasses
import threading

import jax
import numpy as np

from repro.core.orchestrator import Clock
from repro.core.power import PowerParams
from repro.core.state import TwinConfig, TwinState, WindowOutput, init_twin_state
from repro.core.twin import (
    fleet_step_masked,
    index_twin_state,
    stack_twin_states,
    update_twin_state_lane,
)
from repro.serve.batching import (
    SIM_COLUMNS,
    LaneMap,
    WindowManager,
    build_fleet_inputs,
)
from repro.serve.cache import (
    ResultCache,
    decode_result,
    digest_arrays,
    digest_bytes,
    encode_result,
)
from repro.serve.producers import Producer, WindowEvent
from repro.serve.sessions import Session, SessionStore


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Static shape of a service: twin config, lane count, queue, cache.

    ``columns`` fixes the optional :class:`~repro.core.state.SimSlice`
    forecast columns every event must carry (and no others): the compiled
    program's input *structure* is part of the service's identity, so it
    is declared up front rather than inferred from traffic.
    """

    twin: TwinConfig = TwinConfig()
    base_params: PowerParams = PowerParams()
    lanes: int = 16
    queue_capacity: int = 256
    cache: bool = True
    cache_entries: int = 256
    columns: "tuple[str, ...]" = ()
    #: live-mode idle pacing (seconds of injected-clock sleep)
    poll_seconds: float = 0.05
    #: dispatched-but-unharvested batches to keep in flight
    inflight_depth: int = 1
    #: shard the lane (D) axis over the device mesh: every dispatch runs
    #: :func:`~repro.core.twin.fleet_step_masked` with ``shard=True``
    #: (bit-for-bit vs the vmap path), spreading resident tenants across
    #: devices.  Pick ``lanes`` as a multiple of the device count (>= 2 per
    #: device) so dispatches skip the per-call padding copy.
    shard: bool = False
    #: explicit device mesh for ``shard=True`` (default: fleet_mesh())
    mesh: "object | None" = None

    def __post_init__(self):
        bad = set(self.columns) - set(SIM_COLUMNS)
        if bad:
            raise ValueError(
                f"unknown sim columns {sorted(bad)}; choose from "
                f"{SIM_COLUMNS}")
        if self.mesh is not None and not self.shard:
            raise ValueError("mesh given but shard=False")


@dataclasses.dataclass
class ServeStats:
    """Service counters (the numbers ``BENCH_serve.json`` snapshots)."""

    windows_served: int = 0    # results emitted (computed + cached)
    windows_computed: int = 0  # served by the compiled program
    windows_cached: int = 0    # served by a cache hit
    batches: int = 0           # fleet_step_masked dispatches
    lanes_stepped: int = 0     # active lanes summed over batches
    queue_rejects: int = 0     # submits bounced by the bounded queue
    stale_dropped: int = 0     # already-served replays dropped on ingest

    @property
    def fill_ratio(self) -> float:
        """Mean fraction of lanes active per dispatched batch."""
        total = self.batches * max(1, self._lanes)
        return self.lanes_stepped / total if self.batches else 0.0

    _lanes: int = 0  # set by the service; not a counter


@dataclasses.dataclass(frozen=True)
class WindowResult:
    """One emitted tenant-window: the output, and how it was served."""

    tenant: str
    window: int
    output: WindowOutput   # host (numpy) leaves
    cached: bool


@dataclasses.dataclass
class _Inflight:
    """One dispatched batch awaiting harvest."""

    outs: WindowOutput                       # [L, ...] device leaves
    entries: "list[tuple[str, int, tuple, TwinState]]"
    # (tenant, lane, cache key, successor lane state sliced at dispatch)


class TwinService:
    """Multiplex live tenant twins onto one compiled fleet program."""

    def __init__(self, cfg: ServeConfig = ServeConfig(), *,
                 clock: Clock = Clock()):
        self.cfg = cfg
        self.clock = clock
        self.stats = ServeStats(_lanes=cfg.lanes)
        self.cache = ResultCache(cfg.cache_entries) if cfg.cache else None
        self._lanes = LaneMap(cfg.lanes)
        self._windows = WindowManager()
        self._queue: "collections.deque[WindowEvent]" = collections.deque()
        self._producers: "list[Producer]" = []
        self._fleet = stack_twin_states(
            [init_twin_state(cfg.twin, cfg.base_params)] * cfg.lanes)
        self._next_window: dict[str, int] = {}
        self._digest: dict[str, str] = {}
        self._emit_next: dict[str, int] = {}
        self._staged: dict[str, dict[int, WindowResult]] = {}
        self._inflight: "collections.deque[_Inflight]" = collections.deque()
        self._results: "list[WindowResult]" = []
        self._lock = threading.RLock()
        self._stop_event = threading.Event()
        self._thread: "threading.Thread | None" = None

    # -- admission / eviction (control plane) ----------------------------

    def admit(self, tenant: str, state: "TwinState | None" = None, *,
              digest: "str | None" = None, next_window: int = 0) -> int:
        """Land a tenant on a free lane; returns the lane index.

        Fresh tenants start from :func:`~repro.core.state.init_twin_state`
        (the service's ``twin``/``base_params`` config); restored tenants
        pass their checkpointed ``state``/``digest``/``next_window``.
        """
        with self._lock:
            lane = self._lanes.admit(tenant)
            if state is None:
                state = init_twin_state(self.cfg.twin, self.cfg.base_params)
            try:
                self._fleet = update_twin_state_lane(self._fleet, lane, state)
            except ValueError:
                self._lanes.evict(tenant)
                raise
            if digest is None:
                digest = digest_arrays(*jax.tree_util.tree_leaves(state))
            self._next_window[tenant] = int(next_window)
            self._digest[tenant] = digest
            self._emit_next[tenant] = int(next_window)
            self._staged.setdefault(tenant, {})
            return lane

    def evict(self, tenant: str) -> Session:
        """Free a tenant's lane; returns its session (re-admittable).

        In-flight batches are harvested first so the returned state is the
        successor of every window the tenant was dispatched.  Buffered
        not-yet-served windows are dropped — replayable producers re-emit
        them on re-admission.
        """
        with self._lock:
            while self._inflight:
                self._harvest_one()
            session = Session(
                tenant=tenant,
                state=index_twin_state(self._fleet, self._lanes.lane(tenant)),
                next_window=self._next_window[tenant],
                digest=self._digest[tenant],
            )
            self._lanes.evict(tenant)
            self._windows.drop(tenant)
            self._queue = collections.deque(
                ev for ev in self._queue if ev.tenant != tenant)
            for d in (self._next_window, self._digest, self._emit_next,
                      self._staged):
                d.pop(tenant, None)
            return session

    @property
    def tenants(self) -> "list[str]":
        return self._lanes.tenants

    # -- ingestion --------------------------------------------------------

    def submit(self, event: WindowEvent) -> bool:
        """Queue one window; False when the bounded queue is full."""
        if event.tenant not in self._lanes:
            raise ValueError(
                f"tenant {event.tenant!r} is not admitted — call "
                "admit() before streaming")
        with self._lock:
            if len(self._queue) >= self.cfg.queue_capacity:
                self.stats.queue_rejects += 1
                return False
            self._queue.append(event)
            return True

    def attach(self, producer: Producer) -> None:
        """Register a replayable producer for :meth:`pump` to poll."""
        self._producers.append(producer)

    def pump(self, now: "float | None" = None) -> int:
        """Poll every producer at ``now`` (injected clock by default).

        Queued-full backpressure rewinds the producer to the rejected
        window — nothing is lost, the stream re-emits on the next pump.
        Returns the number of events queued.
        """
        if now is None:
            now = self.clock.now()
        queued = 0
        for producer in self._producers:
            for ev in producer.poll(now):
                if self.submit(ev):
                    queued += 1
                else:
                    producer.rewind(ev.window)
                    break
        return queued

    # -- the serving step -------------------------------------------------

    def _drain_queue(self) -> None:
        while self._queue:
            ev = self._queue.popleft()
            if ev.tenant not in self._lanes:
                self.stats.stale_dropped += 1
                continue
            if not self._windows.add(ev, self._next_window[ev.tenant]):
                self.stats.stale_dropped += 1

    def _scenario_digest(self, ev: WindowEvent) -> str:
        return digest_arrays(
            ev.u_th, ev.power_w, ev.sim_u,
            *(getattr(ev, c) for c in self.cfg.columns))

    def _advance(self, tenant: str, scenario_digest: str) -> None:
        # the rolling stream digest: host metadata only, advanced at
        # dispatch so back-to-back windows of one tenant can occupy
        # consecutive in-flight batches
        self._digest[tenant] = digest_bytes(
            self._digest[tenant].encode(), scenario_digest.encode())
        self._next_window[tenant] += 1

    def _stage(self, result: WindowResult) -> None:
        staged = self._staged[result.tenant]
        staged[result.window] = result
        while self._emit_next[result.tenant] in staged:
            w = self._emit_next[result.tenant]
            self._results.append(staged.pop(w))
            self._emit_next[result.tenant] = w + 1
            self.stats.windows_served += 1

    def _dispatch(self, ready: "dict[str, tuple[WindowEvent, tuple]]") -> None:
        by_lane = {self._lanes.lane(t): ev for t, (ev, _) in ready.items()}
        telem, sim, active = build_fleet_inputs(
            by_lane, self.cfg.lanes, self.cfg.twin, self.cfg.columns)
        new_fleet, outs = fleet_step_masked(
            self._fleet, telem, sim, active,
            shard=self.cfg.shard, mesh=self.cfg.mesh)
        entries = []
        for tenant, (ev, key) in ready.items():
            lane = self._lanes.lane(tenant)
            # slice the successor lane state NOW: these reads are enqueued
            # before new_fleet is donated into the next dispatch, so the
            # slices are safe independent buffers
            entries.append((tenant, lane, key,
                            index_twin_state(new_fleet, lane)))
        self._fleet = new_fleet
        self._inflight.append(_Inflight(outs=outs, entries=entries))
        self.stats.batches += 1
        self.stats.lanes_stepped += len(ready)

    def _harvest_one(self) -> None:
        batch = self._inflight.popleft()
        for tenant, lane, key, succ in batch.entries:
            out = jax.tree.map(lambda x: np.asarray(x[lane]), batch.outs)
            if self.cache is not None:
                self.cache.put(key, encode_result(out, succ))
            self.stats.windows_computed += 1
            self._stage(WindowResult(tenant=tenant, window=int(out.window),
                                     output=out, cached=False))

    def _step_once(self) -> bool:
        """One scheduling round; True when any work happened."""
        with self._lock:
            self._drain_queue()
            ready: dict[str, tuple[WindowEvent, tuple]] = {}
            hits = 0
            for tenant in self._lanes.tenants:
                ev = self._windows.pop_ready(tenant,
                                             self._next_window[tenant])
                if ev is None:
                    continue
                scen = self._scenario_digest(ev)
                key = (ev.window, self._digest[tenant], scen)
                if self.cache is not None:
                    blob = self.cache.get(key)
                    if blob is not None:
                        out, succ = decode_result(blob)
                        self._fleet = update_twin_state_lane(
                            self._fleet, self._lanes.lane(tenant), succ)
                        self._advance(tenant, scen)
                        self.stats.windows_cached += 1
                        hits += 1
                        self._stage(WindowResult(
                            tenant=tenant, window=ev.window, output=out,
                            cached=True))
                        continue
                ready[tenant] = (ev, key)
                self._advance(tenant, scen)
            if ready:
                self._dispatch(ready)
            progress = bool(ready) or hits > 0
            while len(self._inflight) > (self.cfg.inflight_depth
                                         if ready else 0):
                self._harvest_one()
                progress = True
            return progress

    def run_until_idle(self, *, pump: bool = True) -> "list[WindowResult]":
        """Serve deterministically until nothing is left to do.

        Pumps attached producers (at the injected clock's ``now``), drains
        the queue, batches, harvests — and repeats until no producer emits,
        no window is ready and nothing is in flight.  Returns the results
        emitted by this call, in per-tenant stream order.
        """
        emitted_from = len(self._results)
        while True:
            queued = self.pump() if pump else 0
            progress = self._step_once()
            if not queued and not progress and not self._inflight:
                break
        return self._results[emitted_from:]

    def drain(self) -> "list[WindowResult]":
        """Take every emitted result (clears the emission log)."""
        with self._lock:
            out, self._results = self._results, []
            return out

    @property
    def results(self) -> "list[WindowResult]":
        return list(self._results)

    # -- live mode ---------------------------------------------------------

    def start(self) -> None:
        """Run the serving loop on a thread, paced by the injected clock."""
        if self._thread is not None:
            raise RuntimeError("service already started")
        self._stop_event.clear()

        def loop():
            while not self._stop_event.is_set():
                queued = self.pump()
                progress = self._step_once()
                if not queued and not progress:
                    self.clock.sleep(self.cfg.poll_seconds)

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="twin-service")
        self._thread.start()

    def stop(self) -> None:
        """Stop the live loop and harvest everything in flight."""
        if self._thread is None:
            return
        self._stop_event.set()
        self._thread.join()
        self._thread = None
        with self._lock:
            while self._inflight:
                self._harvest_one()

    # -- sessions ----------------------------------------------------------

    def checkpoint(self, root) -> SessionStore:
        """Persist every resident tenant's session under ``root``.

        In-flight work is harvested first, so each saved session is the
        exact successor of every window that tenant has been served.
        Queued/buffered but unserved windows are *not* persisted — the
        replayable producers re-emit them after :meth:`restore`, and the
        stale-replay filter drops everything below each session's
        ``next_window``.
        """
        with self._lock:
            while self._inflight:
                self._harvest_one()
            store = SessionStore(root)
            for tenant in self._lanes.tenants:
                store.save(Session(
                    tenant=tenant,
                    state=index_twin_state(self._fleet,
                                           self._lanes.lane(tenant)),
                    next_window=self._next_window[tenant],
                    digest=self._digest[tenant],
                ))
            return store

    def restore(self, root) -> "list[str]":
        """Re-admit every tenant checkpointed under ``root``.

        The restored service resumes each stream at its saved
        ``next_window`` with the saved state and digest — outputs from
        here on are bit-for-bit what the uninterrupted service would have
        emitted.
        """
        store = SessionStore(root)
        tenants = store.tenants
        for tenant in tenants:
            s = store.load(tenant)
            self.admit(tenant, s.state, digest=s.digest,
                       next_window=s.next_window)
        return tenants

    # -- introspection -----------------------------------------------------

    def compile_count(self) -> "int | None":
        """Compilations of the shared fleet program (None off private API)."""
        size = fleet_step_masked._cache_size
        return size() if callable(size) else None

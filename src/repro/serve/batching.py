"""Window manager + dynamic batcher: tenants onto fleet lanes.

The sim-worker in upstream OpenDT keeps a *window manager* that assembles
telemetry into complete windows before simulation; this module is that role
plus the piece our core makes possible: packing whatever mix of tenants is
ready into a **fixed-shape** ``[L]``-lane call of
:func:`repro.core.twin.fleet_step_masked`.  Unfilled lanes ride along as
masked padding — the same pad-and-mask trick the scenario engine plays on
the S axis — so one compiled program serves every arrival pattern.

Three pieces, all host-side and purely mechanical:

  * :class:`LaneMap` — which tenant occupies which fleet lane (admission /
    eviction bookkeeping);
  * :class:`WindowManager` — per-tenant reordering buffer: windows may
    arrive in any order, each tenant's stream is released strictly
    in-order (window ``k`` only after ``k-1``);
  * :func:`build_fleet_inputs` — stacks one ready window per active lane
    into the ``[L, ...]`` device pytrees (zeros on empty lanes).
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.state import SimSlice, TelemetrySlice, TwinConfig
from repro.serve.producers import WindowEvent

#: optional per-bin forecast columns a service can thread into SimSlice —
#: the order here fixes the SimSlice leaf order (compile-relevant)
SIM_COLUMNS = ("carbon_intensity", "ambient_c", "price")


class LaneMap:
    """Tenant <-> fleet-lane assignment (the admission/eviction ledger)."""

    def __init__(self, lanes: int):
        self.lanes = int(lanes)
        self._lane_of: dict[str, int] = {}
        self._free: list[int] = list(range(self.lanes - 1, -1, -1))

    def admit(self, tenant: str) -> int:
        """Assign ``tenant`` a free lane (lowest-numbered first)."""
        if tenant in self._lane_of:
            raise ValueError(f"tenant {tenant!r} already admitted")
        if not self._free:
            raise ValueError(
                f"all {self.lanes} fleet lanes occupied — evict a tenant "
                "first or serve with more lanes")
        lane = self._free.pop()
        self._lane_of[tenant] = lane
        return lane

    def evict(self, tenant: str) -> int:
        """Free ``tenant``'s lane and return its index."""
        lane = self._lane_of.pop(tenant)
        self._free.append(lane)
        self._free.sort(reverse=True)
        return lane

    def lane(self, tenant: str) -> int:
        return self._lane_of[tenant]

    def __contains__(self, tenant: str) -> bool:
        return tenant in self._lane_of

    @property
    def tenants(self) -> "list[str]":
        """Resident tenants in lane order (deterministic iteration)."""
        return sorted(self._lane_of, key=self._lane_of.__getitem__)

    @property
    def occupied(self) -> int:
        return len(self._lane_of)


class WindowManager:
    """Per-tenant reordering buffer: any arrival order, in-order release.

    ``add`` buffers an event under ``(tenant, window)``; ``pop_ready``
    hands back the event for exactly the window the tenant's twin expects
    next (or None).  Windows older than the expectation — replays after a
    crash-restore, duplicate deliveries — are dropped on ``add``; the
    service's sessions know how far each stream has advanced.
    """

    def __init__(self):
        self._pending: dict[str, dict[int, WindowEvent]] = {}

    def add(self, event: WindowEvent, next_window: int) -> bool:
        """Buffer ``event``; False when it is a stale (already-served) replay."""
        if event.window < next_window:
            return False
        self._pending.setdefault(event.tenant, {})[event.window] = event
        return True

    def pop_ready(self, tenant: str, next_window: int) -> "WindowEvent | None":
        got = self._pending.get(tenant)
        if not got:
            return None
        ev = got.pop(next_window, None)
        if ev is not None and not got:
            del self._pending[tenant]
        return ev

    def pending(self, tenant: str) -> int:
        return len(self._pending.get(tenant, ()))

    def drop(self, tenant: str) -> None:
        """Forget a tenant's buffered windows (eviction)."""
        self._pending.pop(tenant, None)

    @property
    def empty(self) -> bool:
        return not self._pending


def build_fleet_inputs(events: "dict[int, WindowEvent]", lanes: int,
                       cfg: TwinConfig, columns: "tuple[str, ...]" = ()
                       ) -> "tuple[TelemetrySlice, SimSlice, jax.Array]":
    """Stack one window per active lane into fixed-shape device pytrees.

    ``events`` maps lane index -> the window to serve there; every other
    lane gets zero padding and ``lane_active=False``.  The output shapes
    depend only on ``(lanes, cfg, columns)`` — never on which lanes are
    filled — which is exactly why the service's fleet program compiles
    once.  ``columns`` must name the :data:`SIM_COLUMNS` subset the service
    was configured with; events must carry those columns and no others so
    the compiled input *structure* is stable across batches.
    """
    tw, h = cfg.bins_per_window, cfg.dc.num_hosts
    u = np.zeros((lanes, tw, h), np.float32)
    p = np.zeros((lanes, tw), np.float32)
    valid = np.zeros((lanes,), bool)
    sim_u = np.zeros((lanes, tw, h), np.float32)
    cols = {c: np.zeros((lanes, tw), np.float32) for c in columns}
    active = np.zeros((lanes,), bool)

    for lane, ev in events.items():
        if ev.u_th.shape != (tw, h) or ev.sim_u.shape != (tw, h):
            raise ValueError(
                f"tenant {ev.tenant!r} window {ev.window}: got telemetry "
                f"{ev.u_th.shape} / sim {ev.sim_u.shape}, the service is "
                f"compiled for {(tw, h)} — clip to the window first")
        active[lane] = True
        sim_u[lane] = ev.sim_u
        u[lane] = ev.u_th
        if ev.power_w is not None:
            p[lane] = ev.power_w
            valid[lane] = True
        for c in SIM_COLUMNS:
            col = getattr(ev, c)
            if c in cols:
                if col is None:
                    raise ValueError(
                        f"tenant {ev.tenant!r} window {ev.window}: the "
                        f"service's configured column {c!r} is missing "
                        "from the event")
                cols[c][lane] = col
            elif col is not None:
                raise ValueError(
                    f"tenant {ev.tenant!r} window {ev.window}: column {c!r} "
                    "is not in the service's configured columns — adding it "
                    "mid-stream would recompile the fleet program")

    telem = TelemetrySlice(u_th=jnp.asarray(u), power_w=jnp.asarray(p),
                           valid=jnp.asarray(valid))
    sim = SimSlice(
        u_th=jnp.asarray(sim_u),
        carbon_intensity=(jnp.asarray(cols["carbon_intensity"])
                          if "carbon_intensity" in cols else None),
        ambient_c=(jnp.asarray(cols["ambient_c"])
                   if "ambient_c" in cols else None),
        price=jnp.asarray(cols["price"]) if "price" in cols else None,
    )
    return telem, sim, jnp.asarray(active)

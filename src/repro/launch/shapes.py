"""Assigned input shapes and per-(arch x shape) input ShapeDtypeStructs.

Every model input — including the parameter pytree and decode state — is
produced as ShapeDtypeStructs so the dry-run lowers without allocating.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs import SUBQUADRATIC, get_config
from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def cell_supported(arch: str, shape: str) -> tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic archs."""
    if shape == "long_500k" and arch not in SUBQUADRATIC:
        return False, ("pure full-attention arch: 500k-context decode is a "
                       "quadratic-regime artifact; skipped per assignment "
                       "(DESIGN.md §6)")
    return True, ""


def _frames_for(cfg: ModelConfig, seq: int) -> int:
    """Stub audio frontend: ~4x temporal downsampling of the target length."""
    return max(min(seq // 4, 4096), 64)


def batch_axes(cfg: ModelConfig, shape: ShapeSpec) -> dict[str, tuple]:
    """Logical axes per input tensor (for shardings)."""
    if shape.kind == "train":
        ax: dict[str, tuple] = {"tokens": ("batch", "seq"),
                                "labels": ("batch", "seq")}
        if cfg.family == "vlm":
            ax["vision_embeds"] = ("batch", "patches", None)
            ax["vision_pos"] = ("batch", "patches")
            ax["positions"] = (None, "batch", "seq")
        if cfg.family == "encdec":
            ax["frames"] = ("batch", "frames", None)
        return ax
    if shape.kind == "prefill":
        ax = {"tokens": ("batch", "seq")}
        if cfg.family == "vlm":
            ax["vision_embeds"] = ("batch", "patches", None)
            ax["vision_pos"] = ("batch", "patches")
            ax["positions"] = (None, "batch", "seq")
        if cfg.family == "encdec":
            ax["frames"] = ("batch", "frames", None)
        return ax
    ax = {"token": ("batch", None), "cache_len": ("batch",)}
    if cfg.mrope:
        ax["positions"] = (None, "batch", None)
    return ax


def input_structs(cfg: ModelConfig, shape: ShapeSpec) -> dict[str, Any]:
    """ShapeDtypeStructs for the step's ``batch`` argument."""
    b, s = shape.batch, shape.seq
    i32 = jnp.int32
    dt = jnp.dtype(cfg.dtype)
    sds = jax.ShapeDtypeStruct
    if shape.kind in ("train", "prefill"):
        out: dict[str, Any] = {"tokens": sds((b, s), i32)}
        if shape.kind == "train":
            out["labels"] = sds((b, s), i32)
        if cfg.family == "vlm":
            p = cfg.num_patches
            out["vision_embeds"] = sds((b, p, cfg.d_model), dt)
            out["vision_pos"] = sds((b, p), i32)
            out["positions"] = sds((3, b, s), i32)
        if cfg.family == "encdec":
            out["frames"] = sds((b, _frames_for(cfg, s), cfg.d_model), dt)
        return out
    out = {"token": sds((b, 1), i32), "cache_len": sds((b,), i32)}
    if cfg.mrope:
        out["positions"] = sds((3, b, 1), i32)
    return out


def concrete_inputs(cfg: ModelConfig, shape: ShapeSpec, seed: int = 0
                    ) -> dict[str, Any]:
    """Small-footprint concrete batch (for smoke tests on reduced configs)."""
    key = jax.random.PRNGKey(seed)
    structs = input_structs(cfg, shape)
    out = {}
    for k, v in structs.items():
        if v.dtype == jnp.int32:
            if k == "cache_len":
                out[k] = jnp.full(v.shape, shape.seq // 2, jnp.int32)
            elif k in ("tokens", "labels", "token"):
                out[k] = jax.random.randint(key, v.shape, 0,
                                            min(cfg.vocab, 1000), jnp.int32)
            elif k == "vision_pos":
                out[k] = jnp.broadcast_to(
                    jnp.arange(v.shape[1], dtype=jnp.int32)[None], v.shape)
            else:
                out[k] = jnp.zeros(v.shape, jnp.int32)
        else:
            out[k] = jnp.ones(v.shape, v.dtype) * 0.02
    return out

"""Serving launcher: batched greedy decoding on a reduced config.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
        --reduce 8 --batch 4 --prompt-len 32 --gen 64
"""

from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.steps import make_serve_step, param_specs_for, state_specs_for
from repro.launch.train import reduce_config
from repro.models.common import init_params
from repro.parallel.sharding import ShardingCtx


@functools.lru_cache(maxsize=None)
def _serve_step_jit(cfg):
    """One donating serve jit per config — cached so repeated mains (tests,
    notebooks) reuse the compilation instead of rebuilding it (TC001)."""
    return jax.jit(make_serve_step(cfg, ShardingCtx()), donate_argnums=(1,))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduce", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduce_config(get_config(args.arch), args.reduce)
    max_seq = args.prompt_len + args.gen
    print(f"serving {cfg.name} (reduced x{args.reduce}) batch={args.batch} "
          f"cache={max_seq}", flush=True)

    key = jax.random.PRNGKey(args.seed)
    dtype = jnp.dtype(cfg.dtype)
    params = init_params(param_specs_for(cfg), key, dtype)
    state = init_params(state_specs_for(cfg, args.batch, max_seq),
                        jax.random.PRNGKey(1), dtype)
    # zero caches/states
    state = jax.tree.map(lambda t: jnp.zeros_like(t), state)

    serve = _serve_step_jit(cfg)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 min(cfg.vocab, 1000), jnp.int32)

    batchd = {"cache_len": jnp.zeros((args.batch,), jnp.int32)}
    if cfg.mrope:
        batchd["positions"] = jnp.zeros((3, args.batch, 1), jnp.int32)

    # prefill by stepping the prompt tokens (cache fills token-by-token)
    t0 = time.time()
    tok = prompts[:, 0:1]
    for i in range(args.prompt_len):
        b = {**batchd, "token": prompts[:, i:i + 1],
             "cache_len": jnp.full((args.batch,), i, jnp.int32)}
        if cfg.mrope:
            b["positions"] = jnp.full((3, args.batch, 1), i, jnp.int32)
        tok, state = serve(params, state, b)
    t_prefill = time.time() - t0

    # generate
    out = []
    t0 = time.time()
    for i in range(args.gen):
        pos = args.prompt_len + i
        b = {**batchd, "token": tok[:, None],
             "cache_len": jnp.full((args.batch,), pos, jnp.int32)}
        if cfg.mrope:
            b["positions"] = jnp.full((3, args.batch, 1), pos, jnp.int32)
        tok, state = serve(params, state, b)
        out.append(np.asarray(tok))
    dt = time.time() - t0
    toks = np.stack(out, axis=1)
    print(f"prefill {args.prompt_len} steps in {t_prefill:.2f}s; "
          f"generated {args.gen} x {args.batch} tokens in {dt:.2f}s "
          f"({args.gen*args.batch/dt:.1f} tok/s)", flush=True)
    print("sample:", toks[0][:16].tolist(), flush=True)
    assert np.isfinite(toks).all()


if __name__ == "__main__":
    main()

"""Digital-twin launcher: run the OpenDT closed loop over a SURF-like trace.

    PYTHONPATH=src python -m repro.launch.twin --days 7 --calibrate
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import OrchestratorConfig, run_surf_experiment
from repro.core.calibrate import CalibrationSpec
from repro.traces.schema import DatacenterConfig
from repro.traces.surf import BINS_PER_DAY, SurfTraceSpec, make_surf22_like


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--days", type=float, default=7.0)
    ap.add_argument("--calibrate", action="store_true")
    ap.add_argument("--no-calibrate", dest="calibrate", action="store_false")
    ap.add_argument("--window-hours", type=float, default=3.0)
    ap.add_argument("--mode", choices=["r_only", "joint"], default="r_only")
    ap.add_argument("--refine", type=int, default=0)
    ap.add_argument("--backend", default="xla",
                    choices=["xla", "pallas", "pallas_interpret"])
    ap.add_argument("--seed", type=int, default=22)
    ap.set_defaults(calibrate=True)
    args = ap.parse_args()

    dc = DatacenterConfig()
    w = make_surf22_like(SurfTraceSpec(days=args.days, seed=args.seed), dc)
    t_bins = int(args.days * BINS_PER_DAY)
    cfg = OrchestratorConfig(
        bins_per_window=int(args.window_hours * 12),
        calibration=CalibrationSpec(mode=args.mode,
                                    refine_iters=args.refine),
        kernel_backend=args.backend,
    )
    t0 = time.time()
    res = run_surf_experiment(w, dc, t_bins, calibrate=args.calibrate,
                              cfg=cfg)
    wall = time.time() - t0
    print(f"twinned {args.days:g} days ({t_bins} bins, {w.num_jobs} jobs) "
          f"in {wall:.1f}s  [{'calibrated' if args.calibrate else 'static'}]")
    print(f"overall MAPE: {res.overall_mape:.2f}%")
    for r in res.slo_reports:
        print(f"SLO {r.slo.name}: compliance {r.compliance:.1%} "
              f"(target >= {r.slo.min_compliance:.0%}) -> "
              f"{'MET' if r.met else 'MISSED'}")
    print(f"under-estimation fraction: {res.under_estimation_fraction:.1%}")
    print(f"window MAPEs: {np.round(res.per_window_mape, 2).tolist()[:12]} ...")
    if res.approved_proposals:
        print(f"approved proposals: {len(res.approved_proposals)}")


if __name__ == "__main__":
    main()

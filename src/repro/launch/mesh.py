"""Production mesh construction (function, not module constant: importing
this module never touches jax device state)."""

from __future__ import annotations

import jax

from repro.parallel.sharding import make_mesh_compat


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (one v5e-class pod) or 2x16x16 (two pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "the dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count"
        )
    return make_mesh_compat(shape, axes, devices=devices[:n])


def make_host_mesh():
    """Single-device 'mesh' for smoke tests (1x1 data/model)."""
    return make_mesh_compat((1, 1), ("data", "model"), devices=jax.devices()[:1])

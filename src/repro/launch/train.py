"""Training launcher: real steps on the available devices, fault-tolerant,
with the digital twin ingesting live telemetry.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --steps 100 --seq 256 --batch 8 --reduce 8

``--reduce N`` divides layer count / widths by N for CPU-scale runs (the
full configs are exercised via the dry-run; real training here is for
end-to-end validation and the live-twin example).
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.data.tokens import DataConfig, TokenPipeline
from repro.launch.steps import make_train_step, param_specs_for
from repro.models.common import init_params
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.parallel.sharding import ShardingCtx
from repro.runtime.fault import FaultConfig, FailureInjector, run_with_restarts


def reduce_config(cfg: ModelConfig, factor: int) -> ModelConfig:
    """Scale a config down by ~factor for CPU-scale end-to-end runs."""
    if factor <= 1:
        return cfg
    def sh(x, lo=1):
        return max(x // factor, lo)
    kv = max(sh(cfg.n_kv_heads, 1), 1)
    heads = max(sh(cfg.n_heads, 1), kv)
    heads = (heads // kv) * kv or kv
    repl = dataclasses.replace(
        cfg,
        num_layers=sh(cfg.num_layers, 2),
        d_model=sh(cfg.d_model, 64),
        d_ff=sh(cfg.d_ff, 64) if cfg.d_ff else 0,
        n_heads=heads if cfg.n_heads else 0,
        n_kv_heads=kv if cfg.n_kv_heads else 0,
        head_dim=max(sh(cfg.head_dim, 16), 16) if cfg.head_dim else 0,
        vocab=max(cfg.vocab // factor, 512),
        moe_d_ff=sh(cfg.moe_d_ff, 32) if cfg.moe_d_ff else 0,
        shared_d_ff=sh(cfg.shared_d_ff, 32) if cfg.shared_d_ff else 0,
        n_experts=min(cfg.n_experts, 8) if cfg.moe else 0,
        top_k=min(cfg.top_k, 2) if cfg.moe else 0,
        q_lora=sh(cfg.q_lora, 16) if cfg.q_lora else 0,
        kv_lora=sh(cfg.kv_lora, 16) if cfg.kv_lora else 0,
        qk_nope_dim=max(sh(cfg.qk_nope_dim, 8), 8) if cfg.qk_nope_dim else 0,
        qk_rope_dim=max(sh(cfg.qk_rope_dim, 8), 8) if cfg.qk_rope_dim else 0,
        v_head_dim=max(sh(cfg.v_head_dim, 8), 8) if cfg.v_head_dim else 0,
        d_state=max(sh(cfg.d_state, 16), 16) if cfg.d_state else 0,
        ssm_headdim=max(sh(cfg.ssm_headdim, 16), 16) if cfg.d_state else 64,
        ssd_chunk=64,
        enc_layers=sh(cfg.enc_layers, 1) if cfg.enc_layers else 0,
        dec_layers=sh(cfg.dec_layers, 1) if cfg.dec_layers else 0,
        shared_attn_every=cfg.shared_attn_every,
        shared_attn_lora=sh(cfg.shared_attn_lora, 8) if cfg.shared_attn_lora else 0,
        num_patches=min(cfg.num_patches, 64) if cfg.num_patches else 0,
        mrope_sections=(
            tuple(int(x) for x in _scale_sections(cfg, factor))
            if cfg.mrope else cfg.mrope_sections),
    )
    return repl.validate()


def _scale_sections(cfg: ModelConfig, factor: int):
    hd = max(cfg.head_dim // factor, 16)
    half = hd // 2
    t = max(half // 4, 1)
    rest = half - t
    h = rest // 2
    w = rest - h
    return (t, h, w)


@functools.lru_cache(maxsize=None)
def _train_step_jit(cfg: ModelConfig, opt_cfg: AdamWConfig):
    """One train-step jit per (model, optimizer) config — cached at module
    scope so repeated mains reuse the compilation (TC001)."""
    return jax.jit(make_train_step(cfg, opt_cfg, ShardingCtx()))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--reduce", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = reduce_config(get_config(args.arch), args.reduce)
    print(f"arch={cfg.name} reduced x{args.reduce}: L={cfg.num_layers} "
          f"d={cfg.d_model} vocab={cfg.vocab}", flush=True)

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=min(50, args.steps // 5),
                          total_steps=args.steps)
    step_fn_jit = _train_step_jit(cfg, opt_cfg)   # single device
    pipe = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                    global_batch=args.batch, seed=args.seed))

    def make_state():
        key = jax.random.PRNGKey(args.seed)
        params = init_params(param_specs_for(cfg), key, jnp.dtype(cfg.dtype))
        opt = init_opt_state(params, opt_cfg)
        return {"params": params, "opt": opt}

    times = []

    def step_fn(state, step):
        batch = pipe.global_batch(step)
        if cfg.family == "encdec":
            batch["frames"] = jnp.ones(
                (args.batch, 64, cfg.d_model), jnp.dtype(cfg.dtype)) * 0.02
        if cfg.family == "vlm":
            p = cfg.num_patches
            batch["vision_embeds"] = jnp.ones(
                (args.batch, p, cfg.d_model), jnp.dtype(cfg.dtype)) * 0.02
            batch["vision_pos"] = jnp.broadcast_to(
                jnp.arange(p, dtype=jnp.int32)[None], (args.batch, p))
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(args.seq, dtype=jnp.int32)[None, None],
                (3, args.batch, args.seq))
        t0 = time.time()
        params, opt, metrics = step_fn_jit(state["params"], state["opt"],
                                           batch)
        loss = float(metrics["loss"])
        times.append(time.time() - t0)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"{times[-1]*1e3:.0f} ms", flush=True)
        return {"params": params, "opt": opt}, loss

    report = run_with_restarts(
        total_steps=args.steps,
        make_state=make_state,
        step_fn=step_fn,
        fault_cfg=FaultConfig(ckpt_dir=args.ckpt_dir,
                              ckpt_every=args.ckpt_every),
        injector=FailureInjector(tuple(args.fail_at)) if args.fail_at else None,
    )
    print(f"done: {report.steps_done} steps, {report.restarts} restarts, "
          f"{report.checkpoints} checkpoints, "
          f"median step {np.median(times)*1e3:.0f} ms, "
          f"final loss {report.losses[-1]:.4f}", flush=True)


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell: build the production mesh, abstract params/opt/caches
(ShapeDtypeStructs — zero allocation), jit the step with explicit
in/out shardings, .lower().compile(), then record memory_analysis(),
cost_analysis(), and the trip-count-corrected HLO costs + roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.hlo import analyze_compiled_text
from repro.analysis.roofline import make_roofline, model_flops_for
from repro.configs import all_archs, get_config
from repro.configs.base import ModelConfig
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, ShapeSpec, batch_axes, cell_supported, input_structs
from repro.launch.steps import (
    make_prefill_step,
    make_serve_step,
    make_train_step,
    param_specs_for,
    state_specs_for,
)
from repro.models.common import abstract_params, specs_to_shardings
from repro.optim.adamw import AdamWConfig, abstract_opt_state
from repro.parallel.sharding import ShardingCtx, logical_to_spec


def batch_shardings(cfg: ModelConfig, shape: ShapeSpec, mesh, mode: str):
    structs = input_structs(cfg, shape)
    axes = batch_axes(cfg, shape)
    return {
        k: NamedSharding(mesh, logical_to_spec(axes[k], v.shape, mesh, mode))
        for k, v in structs.items()
    }


def dryrun_cell(arch: str, shape_name: str, multi_pod: bool,
                verbose: bool = True) -> dict:
    shape = SHAPES[shape_name]
    ok, reason = cell_supported(arch, shape_name)
    mesh_name = "multi" if multi_pod else "single"
    meta = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if not ok:
        return {**meta, "status": "skipped", "reason": reason}

    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    mode = "train" if shape.kind == "train" else "serve"
    ctx = ShardingCtx(mesh=mesh, mode=mode)
    dtype = jnp.dtype(cfg.dtype)

    pspecs = param_specs_for(cfg)
    p_abs = abstract_params(pspecs, dtype)
    p_shard = specs_to_shardings(pspecs, mesh, mode)
    b_abs = input_structs(cfg, shape)
    b_shard = batch_shardings(cfg, shape, mesh, mode)
    rep = NamedSharding(mesh, P())

    t0 = time.time()
    if shape.kind == "train":
        opt_cfg = AdamWConfig()
        o_abs = abstract_opt_state(p_abs, opt_cfg)
        # moments shard exactly like their parameter; step is replicated
        o_shard = type(o_abs)(step=rep, mu=p_shard, nu=p_shard)
        step = make_train_step(cfg, opt_cfg, ctx)
        # tracecheck: disable=TC001 — per-cell AOT lower/compile is the product
        jitted = jax.jit(
            step,
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard, None),
            donate_argnums=(0, 1),
        )
        lowered = jitted.lower(p_abs, o_abs, b_abs)
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg, ctx)
        # tracecheck: disable=TC001 — per-cell AOT lower/compile is the product
        jitted = jax.jit(step, in_shardings=(p_shard, b_shard),
                         out_shardings=None)
        lowered = jitted.lower(p_abs, b_abs)
    else:
        sspecs = state_specs_for(cfg, shape.batch, shape.seq)
        s_abs = abstract_params(sspecs, dtype)
        s_shard = specs_to_shardings(sspecs, mesh, mode)
        step = make_serve_step(cfg, ctx)
        # tracecheck: disable=TC001 — per-cell AOT lower/compile is the product
        jitted = jax.jit(
            step,
            in_shardings=(p_shard, s_shard, b_shard),
            out_shardings=(None, s_shard),
            donate_argnums=(1,),
        )
        lowered = jitted.lower(p_abs, s_abs, b_abs)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo_text = compiled.as_text()
    parsed = analyze_compiled_text(hlo_text, chips)
    mf = model_flops_for(cfg, shape.kind, shape.batch, shape.seq,
                         shape.kind == "train")
    roof = make_roofline(parsed, mf, chips)

    out = {
        **meta,
        "status": "ok",
        "chips": chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes_per_device": mem.argument_size_in_bytes,
            "output_bytes_per_device": mem.output_size_in_bytes,
            "temp_bytes_per_device": mem.temp_size_in_bytes,
            "alias_bytes_per_device": mem.alias_size_in_bytes,
            "peak_bytes_per_device": (
                mem.argument_size_in_bytes + mem.output_size_in_bytes
                + mem.temp_size_in_bytes - mem.alias_size_in_bytes),
        },
        "cost_analysis_flops": float(ca.get("flops", -1.0)),
        "hlo": parsed,
        "roofline": roof.to_dict(),
    }
    if verbose:
        peak_gb = out["memory"]["peak_bytes_per_device"] / 1e9
        print(f"[{arch} x {shape_name} x {mesh_name}] ok "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s "
              f"peak {peak_gb:.2f} GB/dev "
              f"dominant={roof.dominant} "
              f"terms(c/m/n)=({roof.compute_s:.4f}/{roof.memory_s:.4f}/"
              f"{roof.collective_s:.4f})s "
              f"useful={roof.useful_flops_fraction:.2f}", flush=True)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = all_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mesh_name in meshes:
                path = os.path.join(args.out, f"{arch}__{shape}__{mesh_name}.json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[{arch} x {shape} x {mesh_name}] cached", flush=True)
                    continue
                try:
                    res = dryrun_cell(arch, shape, mesh_name == "multi")
                except Exception as e:  # noqa: BLE001 — record and continue
                    failures += 1
                    res = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": "error", "error": repr(e),
                           "traceback": traceback.format_exc()}
                    print(f"[{arch} x {shape} x {mesh_name}] ERROR {e!r}",
                          flush=True)
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
    print(f"dry-run complete; {failures} failures", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()

"""Step factories: train_step / prefill_step / serve_step per architecture.

These are the jitted units the launcher, the dry-run, and the examples all
share.  Shardings for params/opt/caches come from the ParamSpec trees;
shardings for batches come from launch.shapes.batch_axes.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec as ed
from repro.models import lm
from repro.models.common import dense, rms_norm
from repro.optim.adamw import AdamWConfig, apply_updates
from repro.parallel.sharding import ShardingCtx, use_ctx


def loss_for(cfg: ModelConfig):
    if cfg.family == "encdec":
        return ed.encdec_loss
    return lm.loss_fn


def param_specs_for(cfg: ModelConfig):
    if cfg.family == "encdec":
        return ed.encdec_specs(cfg)
    return lm.model_specs(cfg)


def state_specs_for(cfg: ModelConfig, batch: int, seq: int):
    if cfg.family == "encdec":
        return ed.encdec_state_specs(cfg, batch, seq)
    return lm.decode_state_specs(cfg, batch, seq)


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    ctx: ShardingCtx = ShardingCtx(),
                    grad_accum: int = 1):
    """One optimizer step; ``grad_accum`` > 1 splits the batch into
    microbatches scanned sequentially (elastic re-mesh keeps the global
    batch constant by raising grad_accum when data shards shrink)."""
    loss_fn = loss_for(cfg)

    def _grads(params, batch):
        def lossf(p):
            return loss_fn(cfg, p, batch, ctx)

        return jax.value_and_grad(lossf, has_aux=True)(params)

    def train_step(params, opt_state, batch):
        with use_ctx(ctx):
            if grad_accum == 1:
                (loss, metrics), grads = _grads(params, batch)
            else:
                def split(x):
                    b = x.shape[0] if x.ndim and x.shape[0] > 3 else None
                    if b is None or b % grad_accum:
                        raise ValueError("batch not divisible by grad_accum")
                    return x.reshape((grad_accum, b // grad_accum)
                                     + x.shape[1:])

                micro = {k: split(v) for k, v in batch.items()
                         if k != "positions"}

                def body(carry, mb):
                    g_acc, l_acc = carry
                    (l, _), g = _grads(params, mb)
                    g_acc = jax.tree.map(
                        lambda a, b_: a + b_.astype(jnp.float32), g_acc, g)
                    return (g_acc, l_acc + l), None

                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (grads, loss_sum), _ = jax.lax.scan(
                    body, (g0, jnp.zeros((), jnp.float32)), micro)
                grads = jax.tree.map(lambda g: g / grad_accum, grads)
                loss = loss_sum / grad_accum
                metrics = {"ce": loss,
                           "moe_aux": jnp.zeros((), jnp.float32),
                           "tokens": jnp.zeros((), jnp.int32)}
            params, opt_state, om = apply_updates(params, grads, opt_state,
                                                  opt_cfg)
            out = {"loss": loss, **metrics, **om}
            return params, opt_state, out

    return train_step


def make_prefill_step(cfg: ModelConfig, ctx: ShardingCtx = ShardingCtx()):
    """Prefill: hidden states -> LAST-position logits only (the [B, S, V]
    logits tensor is never materialized — vocab 256k x 32k seq would be TBs).
    Cache write-out is elided in the dry-run cell (documented)."""

    def prefill_step(params, batch):
      with use_ctx(ctx):
        if cfg.family == "encdec":
            enc_out = ed.encode(cfg, params, batch["frames"], ctx)
            x = ed.decode_train(cfg, params, batch["tokens"], enc_out, ctx)
            w = params["unembed"]
        else:
            x, _ = lm.backbone(cfg, params, batch, ctx)
            w = lm._unembed_matrix(cfg, params)
        return dense(x[:, -1], w)            # [B, vocab]


    return prefill_step


def make_serve_step(cfg: ModelConfig, ctx: ShardingCtx = ShardingCtx()):
    """One-token greedy decode against the cache/state."""

    def serve_step(params, state, batch):
      with use_ctx(ctx):
        if cfg.family == "encdec":
            logits, state = ed.encdec_decode_step(cfg, params, state, batch,
                                                  ctx)
        else:
            logits, state = lm.decode_step(cfg, params, state, batch, ctx)
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return token, state

    return serve_step

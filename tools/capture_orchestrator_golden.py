"""Capture the orchestrator's per-window outputs as a golden npz.

Pins per-window MAPE, per-window gCO2, the pipelined parameter stream, the
per-window predicted power traces, and the SLO/bias accumulator totals.

Two goldens live in tests/golden/:

  * ``orchestrator_pre_core.npz`` — captured from the PRE-redesign
    (imperative, eager) Orchestrator.  The pure-core shell matches its
    discrete stream (params, proposals, SLO/bias counts) bit-for-bit and
    its float streams to float32-ulp FMA noise (the prediction now runs
    inside one fused jit program).  Do not regenerate.
  * ``orchestrator_core.npz`` — captured from the redesigned pure core;
    the suite pins this one bit-for-bit.  Regenerate (only) on an
    intentional numerical change:

        PYTHONPATH=src python tools/capture_orchestrator_golden.py \
            orchestrator_core.npz
"""

from __future__ import annotations

import pathlib
import sys

import numpy as np

from repro.core.orchestrator import Orchestrator, OrchestratorConfig
from repro.core.twin import TraceGroundTruth
from repro.traces.carbon import make_diurnal_carbon
from repro.traces.schema import DatacenterConfig
from repro.traces.surf import BINS_PER_DAY, SurfTraceSpec, make_surf22_like

GOLDEN_DIR = pathlib.Path(__file__).resolve().parent.parent / "tests" / "golden"
OUT = GOLDEN_DIR / (sys.argv[1] if len(sys.argv) > 1
                    else "orchestrator_core.npz")

#: the window deliberately left without telemetry (pins the no-telemetry path)
SKIP_WINDOW = 5


def main() -> None:
    days = 2.0
    dc = DatacenterConfig(num_hosts=48, cores_per_host=16)
    w = make_surf22_like(SurfTraceSpec(days=days, seed=9), dc)
    t_bins = int(days * BINS_PER_DAY)
    ci = make_diurnal_carbon(t_bins, seed=4)
    cfg = OrchestratorConfig(bins_per_window=36)

    orch = Orchestrator(w, dc, t_bins, cfg, carbon_intensity=ci)
    truth = TraceGroundTruth(w, dc, t_bins)
    for win in range(orch.num_windows):
        if win != SKIP_WINDOW:
            orch.store.ingest(truth.window(win, cfg.bins_per_window))
        orch.run_window(win)

    recs = orch.records
    rep = orch.monitor.report()[0]
    np.savez(
        OUT,
        mape=np.array([np.nan if r.mape is None else r.mape for r in recs],
                      np.float64),
        gco2=np.array([np.nan if r.gco2 is None else r.gco2 for r in recs],
                      np.float64),
        p_idle=np.array([float(np.asarray(r.params.p_idle).mean())
                         for r in recs], np.float64),
        p_max=np.array([float(np.asarray(r.params.p_max).mean())
                        for r in recs], np.float64),
        r=np.array([float(np.asarray(r.params.r).mean()) for r in recs],
                   np.float64),
        power_w=np.stack([np.asarray(r.prediction.power_w, np.float32)
                          for r in recs]),
        proposals=np.array([r.proposals for r in recs], np.int64),
        overall_mape=np.float64(orch.overall_mape()),
        bias=np.array([orch.bias.under, orch.bias.over, orch.bias.ties],
                      np.int64),
        slo=np.array([rep.samples, rep.compliant], np.int64),
        skip_window=np.int64(SKIP_WINDOW),
    )
    print(f"wrote {OUT}: {len(recs)} windows, "
          f"overall MAPE {orch.overall_mape():.3f}%")


if __name__ == "__main__":
    main()

"""Docs checks: markdown link integrity + docstring doctests.

Offline by design (CI runs without network): external http(s) links are
recorded but not fetched; relative links must resolve to files inside the
repo.  Doctests run over the public-API modules that carry examples.

    PYTHONPATH=src python tools/check_docs.py
"""

from __future__ import annotations

import doctest
import importlib
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

#: markdown files whose links must resolve
MARKDOWN = ["README.md", "ROADMAP.md", *sorted(
    str(p.relative_to(REPO)) for p in (REPO / "docs").glob("*.md"))]

#: modules whose docstring examples must execute
DOCTEST_MODULES = [
    "repro.core.desim",
    "repro.core.optimize",
    "repro.core.scenarios",
    "repro.core.codec",
    "repro.core.state",
    "repro.traces.schema",
    "repro.traces.thermal",
    "repro.traces.price",
    "repro.serve.producers",
    "repro.serve.batching",
    "repro.serve.cache",
    "repro.serve.sessions",
    "repro.serve.service",
]

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def check_links() -> list[str]:
    errors = []
    for rel in MARKDOWN:
        md = REPO / rel
        if not md.exists():
            errors.append(f"{rel}: file missing")
            continue
        for target in _LINK.findall(md.read_text()):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                errors.append(f"{rel}: broken link -> {target}")
    return errors


def run_doctests() -> tuple[list[str], int]:
    errors, attempted = [], 0
    for name in DOCTEST_MODULES:
        try:
            mod = importlib.import_module(name)
        except Exception as e:  # import failure is a docs failure too
            errors.append(f"{name}: import failed: {e}")
            continue
        result = doctest.testmod(mod, verbose=False)
        attempted += result.attempted
        if result.failed:
            errors.append(f"{name}: {result.failed} doctest failure(s)")
    return errors, attempted


def main() -> int:
    errors = check_links()
    doc_errors, attempted = run_doctests()
    errors += doc_errors
    if attempted == 0:
        errors.append("no doctests ran — public-API examples went missing")
    for e in errors:
        print(f"FAIL: {e}", file=sys.stderr)
    print(f"checked {len(MARKDOWN)} markdown files, "
          f"ran {attempted} doctests: "
          f"{'FAIL' if errors else 'OK'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())

"""Capture the fused-readout precision-policy golden as an npz.

Pins the ``precision="bf16"`` readout of ``repro.kernels.des_readout``
bit-for-bit on one fixed randomized case with every axis active, next to
its f32 run — so any drift in the precision policy (a leaf silently moving
to bf16, a changed rounding point, a widened matmul) shows up as a golden
diff instead of a quiet accuracy change.  The paired f32 arrays double as
the in-test bound: bf16 may only touch ``tflops``/``efficiency``, and only
within a few bf16 ulps (far inside the ``tests/reference.py`` oracle
tolerance the engine is held to).

Regenerate (only) on an intentional change to the precision policy:

    PYTHONPATH=src python tools/capture_readout_golden.py

Same pattern as ``capture_optimize_golden.py``: the test
(``tests/test_des_kernel.py::test_bf16_golden_pinned``) re-runs this exact
configuration and compares with ``assert_array_equal``.
"""

from __future__ import annotations

import pathlib

import numpy as np

from repro.kernels.des_readout import READOUT_FIELDS, des_readout_ref

OUT = (pathlib.Path(__file__).resolve().parent.parent
       / "tests" / "golden" / "readout_bf16.npz")

#: the pinned configuration — the golden test mirrors these exactly
SEED = 20260808
T, H = 150, 11


def case():
    """The exact (u_th, kwargs) the golden run and the golden test share."""
    rng = np.random.default_rng(SEED)
    fs = np.where(rng.uniform(size=H) < 0.4,
                  rng.integers(0, T, H),
                  np.iinfo(np.int32).max).astype(np.int32)
    rough = 11 * 200.0
    return rng.uniform(0.0, 1.1, (T, H)).astype(np.float32), dict(
        p_idle=rng.uniform(40.0, 90.0, H).astype(np.float32),
        p_max=rng.uniform(200.0, 420.0, H).astype(np.float32),
        r=np.float32(2.3),
        mask=rng.uniform(size=H) < 0.85,
        cap_t=rng.uniform(0.4 * rough, 1.2 * rough, T).astype(np.float32),
        intensity=rng.uniform(50.0, 600.0, T).astype(np.float32),
        ambient=rng.uniform(-5.0, 38.0, T).astype(np.float32),
        price=rng.uniform(0.01, 0.45, T).astype(np.float32),
        peak_tflops=np.float32(250.0),
        pue_base=np.float32(1.18), pue_amb_coeff=np.float32(0.01),
        pue_amb_ref=np.float32(18.0), pue_load_coeff=np.float32(0.12),
        fail_start=fs,
        fail_end=np.minimum(fs.astype(np.int64) + 30,
                            np.iinfo(np.int32).max).astype(np.int32),
        fail_kill=rng.uniform(size=H) < 0.6,
        tb_t=64)


def run():
    u, kw = case()
    return (des_readout_ref(u, **kw, precision="bf16"),
            des_readout_ref(u, **kw))


def main() -> None:
    bf16, f32 = run()
    np.savez(OUT,
             **{f"bf16_{k}": np.asarray(bf16[k]) for k in READOUT_FIELDS},
             **{f"f32_{k}": np.asarray(f32[k]) for k in READOUT_FIELDS})
    moved = [k for k in READOUT_FIELDS
             if not np.array_equal(np.asarray(bf16[k]), np.asarray(f32[k]))]
    print(f"wrote {OUT}: T={T} H={H}; bf16 moved only {moved}")


if __name__ == "__main__":
    main()

"""Capture a deterministic optimizer trajectory as a golden npz.

Pins the scenario optimizer's full evaluation stream — every candidate's
objective, feasibility flag, generation and lane, the incumbent convergence
curve, and the winning operating point's knobs — bit-for-bit, so any change
to the sampling, halving schedule, scoring, or the underlying evaluator
shows up as a golden diff instead of a silent behavior drift.

Regenerate (only) on an intentional change to optimizer numerics:

    PYTHONPATH=src python tools/capture_optimize_golden.py

Same pattern as ``capture_orchestrator_golden.py``: the test
(``tests/test_optimize.py::test_trajectory_matches_golden``) re-runs this
exact configuration and compares arrays with ``assert_array_equal``.
"""

from __future__ import annotations

import pathlib

import numpy as np

from repro.core.optimize import (
    ObjectiveSpec,
    OptimizerConfig,
    SearchSpace,
    optimize,
)
from repro.core.scenarios import Scenario
from repro.traces.carbon import make_diurnal_carbon
from repro.traces.schema import DatacenterConfig
from repro.traces.surf import SurfTraceSpec, make_surf22_like

OUT = (pathlib.Path(__file__).resolve().parent.parent
       / "tests" / "golden" / "optimize_trajectory.npz")

#: the pinned configuration — the golden test mirrors these exactly
T_BINS = 72
DC = DatacenterConfig(num_hosts=24, cores_per_host=16)
KEY = 7


def search_inputs():
    """The exact (workload, intensity, space, objective, config) the golden
    run and the golden test share."""
    w = make_surf22_like(SurfTraceSpec(days=0.25, seed=13), DC)
    ci = make_diurnal_carbon(T_BINS, seed=3)
    space = SearchSpace(
        structures=(
            Scenario(name="wf"),
            Scenario(name="bf", policy="best_fit", backfill_depth=4),
            Scenario(name="h16", num_hosts=16),
        ),
        carbon_cap_base_w=(2_000.0, 6_000.0),
        carbon_cap_slope=(-8.0, 0.0),
        shift_bins=(0, 24),
    )
    objective = ObjectiveSpec(w_gco2_kg=1.0, w_energy_kwh=0.05, w_wait=0.2,
                              w_unplaced=25.0, w_throttled=0.05,
                              max_unplaced_jobs=5)
    config = OptimizerConfig(batch_size=8, generations=3, init="grid",
                             init_levels=2)
    return w, ci, space, objective, config


def run():
    w, ci, space, objective, config = search_inputs()
    return optimize(w, DC, space, objective, t_bins=T_BINS,
                    carbon_intensity=ci, key=KEY, config=config)


def main() -> None:
    res = run()
    np.savez(
        OUT,
        objective=np.array([c.objective for c in res.history], np.float64),
        feasible=np.array([c.feasible for c in res.history], np.bool_),
        generation=np.array([c.generation for c in res.history], np.int64),
        lane=np.array([c.lane for c in res.history], np.int64),
        incumbent_objective=res.incumbent_objective,
        best_objective=np.float64(res.best.objective),
        baseline_objective=np.float64(res.baseline.objective),
        best_gco2_kg=np.float64(res.best.breakdown["gco2_kg"]),
        best_num_hosts=np.int64(res.best_summary.num_hosts),
        best_policy=np.str_(res.best_summary.policy),
        best_backfill=np.int64(res.best_summary.backfill_depth),
        best_shift_bins=np.int64(res.best_summary.shift_bins),
        best_carbon_cap_base_w=np.float64(
            np.nan if res.best_summary.carbon_cap_base_w is None
            else res.best_summary.carbon_cap_base_w),
        best_carbon_cap_slope=np.float64(res.best_summary.carbon_cap_slope),
    )
    print(f"wrote {OUT}: {res.evaluations} evaluations, "
          f"{res.batches} batches, best objective {res.best.objective:.6f} "
          f"(baseline {res.baseline.objective:.6f})")


if __name__ == "__main__":
    main()

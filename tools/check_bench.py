"""Validate the committed ``benchmarks/BENCH_*.json`` performance snapshots.

Two modes, both CI-wired (the bench-snapshot job):

* **schema** (default; spelled ``--validate`` in CI) — every committed
  snapshot parses, carries the provenance fields (``regenerate_with`` /
  ``backend`` / ``devices`` / ``lint_findings``), and
  its invariant fields hold: compile counts are exactly 1, the sharded
  cross-check is either a boolean that is ``true`` or an explicit
  ``"skipped: ..."`` reason string (a bare ``null`` means the check was
  silently dropped — the PR-7 bug this tool exists to catch), and
  wall-clock fields are positive finite numbers.

* **--compare OLD_DIR** — regression gate between two snapshot sets: the
  compile-count invariants must not grow (a retrace regression fails the
  job); wall-clock drift is reported but informational, since the
  committed numbers come from whatever machine regenerated them last.

    PYTHONPATH=src python tools/check_bench.py
    PYTHONPATH=src python tools/check_bench.py --compare /tmp/old_benchmarks
"""

from __future__ import annotations

import argparse
import json
import math
import pathlib
import sys

BENCH_DIR = pathlib.Path(__file__).resolve().parent.parent / "benchmarks"

#: required provenance keys in every snapshot.  ``lint_findings`` is the
#: standing tracecheck debt at regeneration time (see tools/lint): the
#: perf trajectory doubles as the contract-debt trend.
PROVENANCE = ("regenerate_with", "jax_version", "backend", "devices",
              "lint_findings")

#: dotted paths of compile-count invariants per snapshot file; missing
#: entries fail (the invariant was dropped), None values are allowed only
#: if jax stopped exposing the cache hook on the regenerating machine
COMPILE_COUNTS = {
    "BENCH_whatif.json": (
        "optimizer.compiles",
        "new_axes_grid.compiles",
    ),
    "BENCH_des.json": (
        "optimizer.compiles",
        "engine_sweep.legacy_compiles",
        "engine_sweep.pallas_compiles",
    ),
    "BENCH_serve.json": (
        "serve.compiles",
    ),
    "BENCH_fleet.json": (
        "fleet.vmap_compiles",
        "fleet.sharded_compiles",
    ),
}

#: dotted paths that must be positive finite wall-clock seconds
WALL_CLOCKS = {
    "BENCH_whatif.json": (
        "optimizer.warm_s",
        "new_axes_grid.grid_s",
        "window_step.mean_seconds",
        "des_hot_path.scan_s",
        "des_hot_path.total_s",
    ),
    "BENCH_des.json": (
        "des_hot_path.scan_s",
        "des_hot_path.total_s",
        "readout_microbench.legacy_unfused_s",
        "readout_microbench.fused_xla_s",
        "readout_microbench.pallas_s",
        "engine_sweep.legacy_warm_s",
        "engine_sweep.pallas_warm_s",
        "optimizer.warm_s",
    ),
    "BENCH_serve.json": (
        "serve.cold_s",
        "serve.warm_s",
        "serve.replay_s",
    ),
    "BENCH_fleet.json": (
        "fleet.vmap_cold_s",
        "fleet.vmap_warm_s",
        "fleet.sharded_cold_s",
        "fleet.sharded_warm_s",
        "fleet.vmap_window_step_s",
        "fleet.sharded_window_step_s",
    ),
}

#: dotted paths of sharded-vs-vmap cross-checks: must be ``true`` or an
#: explicit ``"skipped: ..."`` reason, never null (the silently-dropped
#: check is the PR-7 bug this tool exists to catch)
BITWISE_CHECKS = {
    "BENCH_whatif.json": ("new_axes_grid.sharded_bitwise_equal",),
    "BENCH_fleet.json": ("fleet.sharded_bitwise_equal",),
}


def _get(snap: dict, path: str):
    cur = snap
    for part in path.split("."):
        if not isinstance(cur, dict) or part not in cur:
            raise KeyError(path)
        cur = cur[part]
    return cur


def check_snapshot(path: pathlib.Path) -> list[str]:
    """All schema violations in one committed snapshot (empty = clean)."""
    errors: list[str] = []
    try:
        snap = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path.name}: unreadable ({e})"]

    for key in PROVENANCE:
        if key not in snap:
            errors.append(f"{path.name}: missing provenance field {key!r}")
    if not isinstance(snap.get("devices"), int) or snap.get("devices", 0) < 1:
        errors.append(f"{path.name}: devices must be a positive int")
    lf = snap.get("lint_findings")
    if lf is not None and (not isinstance(lf, int) or lf < 0):
        errors.append(f"{path.name}: lint_findings must be an int >= 0, "
                      f"got {lf!r}")

    for cpath in COMPILE_COUNTS.get(path.name, ()):
        try:
            v = _get(snap, cpath)
        except KeyError:
            errors.append(f"{path.name}: compile-count field {cpath} missing")
            continue
        if v is None:
            continue  # cache hook unavailable on the regenerating machine
        if v != 1:
            errors.append(f"{path.name}: {cpath} = {v}, want 1 "
                          "(single-compile invariant broken)")

    for wpath in WALL_CLOCKS.get(path.name, ()):
        try:
            v = _get(snap, wpath)
        except KeyError:
            errors.append(f"{path.name}: wall-clock field {wpath} missing")
            continue
        if not isinstance(v, (int, float)) or not math.isfinite(v) or v <= 0:
            errors.append(f"{path.name}: {wpath} = {v!r}, want finite > 0")

    # the sharded cross-checks must be explicit outcomes, never null
    for bpath in BITWISE_CHECKS.get(path.name, ()):
        try:
            sbe = _get(snap, bpath)
        except KeyError:
            sbe = None
        if sbe is None:
            errors.append(
                f"{path.name}: {bpath} is null — record true (checked) or "
                "an explicit 'skipped: ...' reason")
        elif isinstance(sbe, str):
            if not sbe.startswith("skipped:"):
                errors.append(f"{path.name}: {bpath} string must start "
                              f"with 'skipped:', got {sbe!r}")
        elif sbe is not True:
            errors.append(f"{path.name}: {bpath} = {sbe!r} — "
                          "the shard_map path diverged from vmap")
    return errors


def compare_snapshots(old_dir: pathlib.Path) -> tuple[list[str], list[str]]:
    """(failures, infos) between ``old_dir`` and the committed snapshots.

    Compile counts may never grow; wall-clock drift is informational.
    """
    failures: list[str] = []
    infos: list[str] = []
    for name, cpaths in COMPILE_COUNTS.items():
        old_p, new_p = old_dir / name, BENCH_DIR / name
        if not old_p.exists() or not new_p.exists():
            infos.append(f"{name}: missing on one side, compare skipped")
            continue
        old = json.loads(old_p.read_text())
        new = json.loads(new_p.read_text())
        for cpath in cpaths:
            try:
                ov, nv = _get(old, cpath), _get(new, cpath)
            except KeyError as e:
                failures.append(f"{name}: {e.args[0]} missing in one side")
                continue
            if ov is not None and nv is not None and nv > ov:
                failures.append(f"{name}: {cpath} regressed {ov} -> {nv} "
                                "(retrace regression)")
        # contract-debt trend: informational (the lint CI job is the gate
        # for NEW findings; this line makes the trajectory visible)
        ol, nl = old.get("lint_findings"), new.get("lint_findings")
        if isinstance(ol, int) and isinstance(nl, int):
            infos.append(f"{name}: lint_findings {ol} -> {nl}")
        for wpath in WALL_CLOCKS.get(name, ()):
            try:
                ov, nv = _get(old, wpath), _get(new, wpath)
            except KeyError:
                continue
            if isinstance(ov, (int, float)) and isinstance(nv, (int, float)) \
                    and ov > 0:
                infos.append(f"{name}: {wpath} {ov:.4f}s -> {nv:.4f}s "
                             f"({nv / ov - 1.0:+.1%} vs old)")
    return failures, infos


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--compare", metavar="OLD_DIR", default=None,
                    help="old benchmarks/ dir to diff compile counts against")
    ap.add_argument("--validate", action="store_true",
                    help="schema-validate the committed snapshots (the "
                         "default mode, named for CI readability)")
    args = ap.parse_args(argv)

    snaps = sorted(BENCH_DIR.glob("BENCH_*.json"))
    if not snaps:
        print("check_bench: no benchmarks/BENCH_*.json found", file=sys.stderr)
        return 1
    errors: list[str] = []
    for p in snaps:
        errors.extend(check_snapshot(p))

    if args.compare:
        failures, infos = compare_snapshots(pathlib.Path(args.compare))
        errors.extend(failures)
        for line in infos:
            print(f"  info: {line}")

    if errors:
        for e in errors:
            print(f"FAIL: {e}", file=sys.stderr)
        return 1
    print(f"check_bench: {len(snaps)} snapshot(s) OK "
          f"({', '.join(p.name for p in snaps)})")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""tracecheck mechanism: scanning, module index, call graph, baseline.

Pure stdlib ``ast`` — the linter runs without jax installed, so the CI
static gate needs no accelerator stack and finishes in seconds.

The moving parts:

* :class:`SourceFile` — one parsed file: tree, per-line suppressions,
  repo-relative path, dotted module name, import map.
* :class:`Project` — the file set plus everything cross-file: the function
  index, the jit-entry reachability closure (with per-function static
  parameter sets) and the donating-jit registry.
* :func:`run_lint` — parse, run the rules, apply ``# tracecheck:
  disable=…`` suppressions, diff against the baseline.

Baseline contract (the ratchet): ``baseline.json`` holds explicitly
justified findings, each with a non-empty ``reason``.  A finding matching
an entry passes; a finding matching nothing is *new* and fails; an entry
matching nothing is *stale* and also fails (the debt was paid — delete the
entry, don't let it shadow a future regression).  Keys are line-number
free, so pure line drift never churns the baseline.
"""

from __future__ import annotations

import ast
import dataclasses
import json
import pathlib
import re
from collections import Counter

REPO_ROOT = pathlib.Path(__file__).resolve().parents[2]
DEFAULT_BASELINE = pathlib.Path(__file__).resolve().parent / "baseline.json"

_SUPPRESS_RE = re.compile(
    r"#\s*tracecheck:\s*disable=([A-Za-z0-9_,\s]+?)(?:\s+—|\s+--|\s*$)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a concrete location.

    ``key`` is the stable identity used for baseline matching and
    suppression accounting: rule, path and message plus an occurrence
    counter for exact duplicates — deliberately no line number, so a
    finding survives unrelated edits above it.
    """

    rule: str
    path: str          # repo-root-relative, posix separators
    line: int
    message: str
    key: str = ""

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


class SourceFile:
    """One parsed python file plus the lexical facts rules need."""

    def __init__(self, abspath: pathlib.Path, root: pathlib.Path):
        self.abspath = abspath
        self.path = abspath.relative_to(root).as_posix()
        self.source = abspath.read_text()
        self.lines = self.source.splitlines()
        self.tree = ast.parse(self.source, filename=str(abspath))
        self.module = self._module_name()
        # line -> set of rule ids disabled on that line ("all" disables all)
        self.suppressions: dict[int, set[str]] = {}
        for i, text in enumerate(self.lines, start=1):
            m = _SUPPRESS_RE.search(text)
            if m:
                self.suppressions[i] = {
                    r.strip().upper() if r.strip().lower() != "all" else "all"
                    for r in m.group(1).split(",") if r.strip()}
        self._annotate_parents()
        self.import_map = self._collect_imports()

    def _module_name(self) -> str | None:
        parts = list(pathlib.PurePosixPath(self.path).parts)
        if parts[-1].endswith(".py"):
            parts[-1] = parts[-1][:-3]
        if parts and parts[0] == "src":
            parts = parts[1:]
        if parts and parts[-1] == "__init__":
            parts = parts[:-1]
        return ".".join(parts) if parts else None

    def _annotate_parents(self) -> None:
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                child._tc_parent = node  # type: ignore[attr-defined]

    def _collect_imports(self) -> dict[str, str]:
        """local name -> dotted target, for module-level imports."""
        out: dict[str, str] = {}
        for node in self.tree.body:
            if isinstance(node, ast.Import):
                for a in node.names:
                    out[a.asname or a.name.split(".")[0]] = a.name
            elif isinstance(node, ast.ImportFrom) and node.module \
                    and node.level == 0:
                for a in node.names:
                    if a.name == "*":
                        continue
                    out[a.asname or a.name] = f"{node.module}.{a.name}"
        return out

    def ancestors(self, node: ast.AST):
        cur = getattr(node, "_tc_parent", None)
        while cur is not None:
            yield cur
            cur = getattr(cur, "_tc_parent", None)

    def enclosing_function(self, node: ast.AST):
        for anc in self.ancestors(node):
            if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return anc
        return None

    def suppressed(self, rule: str, line: int) -> bool:
        """Trailing comment on the line, or a comment line directly above
        (for multi-line statements where a trailing comment can't fit)."""
        for ln in (line, line - 1):
            rules = self.suppressions.get(ln, ())
            if "all" in rules or rule in rules:
                return True
        return False


def dotted(node: ast.AST) -> str | None:
    """``a.b.c`` for Name/Attribute chains, else None."""
    parts: list[str] = []
    cur = node
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if isinstance(cur, ast.Name):
        parts.append(cur.id)
        return ".".join(reversed(parts))
    return None


def _const_str_tuple(node: ast.AST, sf: SourceFile) -> tuple[str, ...]:
    """Static-argnames value -> tuple of strings (resolving one Name hop)."""
    if isinstance(node, ast.Name):
        # e.g. static_argnames=_RUN_STATICS with a module-level constant
        for stmt in sf.tree.body:
            if isinstance(stmt, ast.Assign) and any(
                    isinstance(t, ast.Name) and t.id == node.id
                    for t in stmt.targets):
                node = stmt.value
                break
    if isinstance(node, (ast.Tuple, ast.List)):
        return tuple(e.value for e in node.elts
                     if isinstance(e, ast.Constant) and isinstance(e.value, str))
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return (node.value,)
    return ()


def is_jax_jit(node: ast.AST, sf: SourceFile) -> bool:
    """True for expressions denoting ``jax.jit`` (incl. ``from jax import jit``)."""
    d = dotted(node)
    if d == "jax.jit":
        return True
    return d is not None and sf.import_map.get(d) == "jax.jit"


def jit_call_info(call: ast.Call, sf: SourceFile):
    """(inner_fn_expr, static_names, donate_positions) if ``call`` builds a
    jit — ``jax.jit(...)`` or ``functools.partial(jax.jit, ...)`` — else None.
    """
    func = call.func
    is_partial = dotted(func) in ("functools.partial", "partial")
    if is_partial:
        if not (call.args and is_jax_jit(call.args[0], sf)):
            return None
        inner = call.args[1] if len(call.args) > 1 else None
    elif is_jax_jit(func, sf):
        inner = call.args[0] if call.args else None
    else:
        return None
    statics: tuple[str, ...] = ()
    donate: tuple[int, ...] = ()
    for kw in call.keywords:
        if kw.arg in ("static_argnames", "static_argnums"):
            statics = statics + _const_str_tuple(kw.value, sf)
        elif kw.arg == "donate_argnums":
            v = kw.value
            elts = v.elts if isinstance(v, (ast.Tuple, ast.List)) else [v]
            donate = tuple(e.value for e in elts
                           if isinstance(e, ast.Constant)
                           and isinstance(e.value, int))
    return inner, statics, donate


@dataclasses.dataclass
class FunctionInfo:
    sf: SourceFile
    node: ast.FunctionDef | ast.AsyncFunctionDef
    qualname: str                       # module.func (module-level only)
    statics: set[str] = dataclasses.field(default_factory=set)


class Project:
    """Cross-file view: function index, call graph, jit-entry closure."""

    def __init__(self, files: list[SourceFile], registry):
        self.files = files
        self.registry = registry
        self.by_module: dict[str, SourceFile] = {
            f.module: f for f in files if f.module}
        # module-level functions by dotted name
        self.functions: dict[str, FunctionInfo] = {}
        for sf in files:
            if not sf.module:
                continue
            for node in sf.tree.body:
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    q = f"{sf.module}.{node.name}"
                    self.functions[q] = FunctionInfo(sf, node, q)
        self.donating: dict[str, tuple[int, ...]] = dict(
            registry.DONATING_JITS)
        self._entry_statics: dict[str, set[str]] = {
            q: set(s) for q, s in registry.JIT_ENTRYPOINTS.items()}
        self._discover_jits()
        self.reachable: dict[str, FunctionInfo] = {}
        self._close_over_entries()

    # -- discovery ------------------------------------------------------------
    def _discover_jits(self) -> None:
        """Auto-register in-place jits: decorated functions and module-level
        ``name = jax.jit(fn, ...)`` assignments (statics + donation)."""
        for sf in self.files:
            if not sf.module:
                continue
            for node in sf.tree.body:
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    for dec in node.decorator_list:
                        info = (jit_call_info(dec, sf)
                                if isinstance(dec, ast.Call) else
                                ((None, (), ()) if is_jax_jit(dec, sf)
                                 else None))
                        if info is not None:
                            q = f"{sf.module}.{node.name}"
                            self._entry_statics.setdefault(
                                q, set()).update(info[1])
                elif isinstance(node, ast.Assign) and isinstance(
                        node.value, ast.Call):
                    info = jit_call_info(node.value, sf)
                    if info is None:
                        continue
                    inner, statics, donate = info
                    inner_name = dotted(inner) if inner is not None else None
                    if inner_name and "." not in inner_name \
                            and f"{sf.module}.{inner_name}" in self.functions:
                        q = f"{sf.module}.{inner_name}"
                        self._entry_statics.setdefault(q, set()).update(statics)
                    for target in node.targets:
                        t = dotted(target)
                        if t and donate:
                            self.donating[f"{sf.module}.{t}"] = donate

    def resolve_call(self, sf: SourceFile, call: ast.Call) -> str | None:
        """Dotted target of a call, resolved through module-level imports."""
        d = dotted(call.func)
        if d is None:
            return None
        head, _, rest = d.partition(".")
        target = sf.import_map.get(head)
        if target is not None:
            d = f"{target}.{rest}" if rest else target
        elif sf.module and "." not in d:
            local = f"{sf.module}.{d}"
            if local in self.functions:
                d = local
        return d

    # -- reachability ---------------------------------------------------------
    def _close_over_entries(self) -> None:
        queue = [q for q in self._entry_statics if q in self.functions]
        seen = set(queue)
        for q in queue:
            self.functions[q].statics |= self._entry_statics.get(q, set())
        while queue:
            q = queue.pop()
            fi = self.functions[q]
            self.reachable[q] = fi
            for node in ast.walk(fi.node):
                if not isinstance(node, ast.Call):
                    continue
                target = self.resolve_call(fi.sf, node)
                if target is None or target not in self.functions:
                    continue
                if target not in seen:
                    seen.add(target)
                    self.functions[target].statics |= \
                        self._entry_statics.get(target, set())
                    queue.append(target)

    def traced_params(self, fi: FunctionInfo) -> set[str]:
        """Parameters of a reachable function considered traced."""
        a = fi.node.args
        params = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
        out = set()
        for p in a.posonlyargs + a.args + a.kwonlyargs:
            if p.arg in fi.statics:
                continue
            if p.arg in self.registry.STATIC_PARAM_NAMES:
                continue
            if self._static_annotation(p):
                continue
            out.add(p.arg)
        del params
        return out

    @staticmethod
    def _static_annotation(p: ast.arg) -> bool:
        """Annotated with a pure host-scalar type -> static by declaration."""
        ann = p.annotation
        if ann is None:
            return False
        text = ast.unparse(ann).strip()
        if text[:1] in ("'", '"'):          # string annotation
            text = text.strip("\"'").strip()
        parts = [t.strip() for t in text.split("|")]
        return all(t in ("str", "bool", "int", "float", "None")
                   for t in parts)


# -- baseline -----------------------------------------------------------------

def load_baseline(path: pathlib.Path) -> list[dict]:
    """Parse and validate baseline entries (every entry needs a reason)."""
    data = json.loads(path.read_text())
    entries = data.get("entries", [])
    for e in entries:
        if not isinstance(e.get("key"), str) or not e["key"]:
            raise ValueError(f"baseline entry without a key: {e!r}")
        if not isinstance(e.get("reason"), str) or not e["reason"].strip():
            raise ValueError(
                f"baseline entry {e['key']!r} has no reason — every "
                "grandfathered finding must say why it is allowed to stand")
    return entries


def assign_keys(findings: list[Finding]) -> list[Finding]:
    """Stable, line-free keys: rule::path::message, deduped by occurrence."""
    seen: Counter[str] = Counter()
    out = []
    for f in sorted(findings, key=lambda f: (f.path, f.line, f.rule)):
        base = f"{f.rule}::{f.path}::{f.message}"
        n = seen[base]
        seen[base] += 1
        out.append(dataclasses.replace(
            f, key=base if n == 0 else f"{base}::{n}"))
    return out


@dataclasses.dataclass
class LintResult:
    findings: list[Finding]        # all post-suppression findings
    new: list[Finding]             # not covered by the baseline -> fail
    baselined: list[Finding]       # covered: the standing contract debt
    stale: list[str]               # baseline keys matching nothing -> fail

    @property
    def ok(self) -> bool:
        return not self.new and not self.stale


def run_lint(paths, root: pathlib.Path | None = None, registry=None,
             baseline_entries: list[dict] | None = None,
             rules=None) -> LintResult:
    """Lint ``paths`` (files or directories) and diff against the baseline."""
    from tools.lint import rules as rules_mod
    from tools.lint import entrypoints as default_registry
    registry = registry or default_registry
    root = (root or REPO_ROOT).resolve()

    files: list[SourceFile] = []
    seen_paths = set()
    for p in paths:
        p = pathlib.Path(p)
        if not p.is_absolute():
            p = root / p
        candidates = ([p] if p.is_file() else sorted(p.rglob("*.py")))
        for c in candidates:
            c = c.resolve()
            if c in seen_paths or "__pycache__" in c.parts:
                continue
            seen_paths.add(c)
            files.append(SourceFile(c, root))

    project = Project(files, registry)
    findings: list[Finding] = []
    for rule_fn in (rules or rules_mod.ALL_RULES):
        findings.extend(rule_fn(project))

    by_path = {f.path: f for f in files}
    kept = [f for f in findings
            if not by_path[f.path].suppressed(f.rule, f.line)]
    kept = assign_keys(kept)

    entries = baseline_entries or []
    entry_keys = {e["key"] for e in entries}
    new = [f for f in kept if f.key not in entry_keys]
    baselined = [f for f in kept if f.key in entry_keys]
    found_keys = {f.key for f in kept}
    stale = [k for k in sorted(entry_keys) if k not in found_keys]
    return LintResult(findings=kept, new=new, baselined=baselined,
                      stale=stale)

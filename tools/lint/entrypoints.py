"""The declared contract surface ``tracecheck`` enforces.

This module is *policy, not mechanism*: it names the jitted entry points of
the twin, the parameters that are static at those boundaries, and the files
allowed to do things that are forbidden elsewhere.  The mechanism lives in
:mod:`tools.lint.engine` / :mod:`tools.lint.rules`.

Keeping the registry in one reviewed file is the point — adding a new jit
entry point, a new bf16 site or a new nondeterminism allowance is a visible
one-line diff here, not an invisible drift in the codebase.
"""

from __future__ import annotations

#: Jitted entry points of the twin: dotted module path -> static parameter
#: names at that boundary.  Everything *reachable* from these functions runs
#: under ``jax.jit`` tracing, so TC002 (no concretization) and TC003 (no
#: Python control flow on traced values) apply to their parameters.
#:
#: The engine additionally auto-registers every module-level function that
#: is jitted in place — ``@functools.partial(jax.jit, static_argnames=...)``
#: decorators and ``name = jax.jit(fn, static_argnames=...)`` assignments —
#: deriving the static set from the decorator/call itself.  List a function
#: here only when it is jitted indirectly (``twin_step`` via
#: ``twin_step_jit``) or its statics cannot be derived syntactically.
JIT_ENTRYPOINTS: dict[str, tuple[str, ...]] = {
    # the pure twin cycle — jitted as state.twin_step_jit (donating) and by
    # callers via jax.jit(twin_step); cfg rides in the pytree as aux data
    "repro.core.state.twin_step": (),
    # the batched scenario engine body behind _run_scenarios_jit[_donated];
    # statics mirror scenarios._RUN_STATICS (also auto-derived, kept here so
    # the contract survives a rename of the module-level alias)
    "repro.core.scenarios._run_scenarios_body": (
        "max_hosts", "t_bins", "max_starts_per_bin", "model",
        "use_pallas", "precision"),
    # the fused per-tile readout shared by the Pallas kernel and its XLA
    # reference — everything after the bare ``*`` is compile-time
    "repro.kernels.des_readout._tile_readout": (
        "model", "precision", "dt_seconds", "tb_t"),
    # fleet twinning: scan(vmap(twin_step)) behind twin._run_fleet_jit
    "repro.core.twin._run_fleet": (),
    # lane-masked fleet step behind twin._fleet_step_masked_jit — the ONE
    # compiled program the streaming service (repro.serve) multiplexes
    # every tenant mix onto
    "repro.core.twin._fleet_step_masked": (),
    # the traced calibration grid (fleet argmin + optional per-host refit,
    # calibrate._per_host_refit) — jitted indirectly inside twin_step and
    # directly by differential tests as jax.jit(..., static_argnames="spec")
    "repro.core.calibrate.calibrate_traced": ("spec",),
    # NOTE: the D-axis sharded fleet programs (twin._run_fleet_sharded_jit /
    # twin._fleet_step_masked_sharded_jit, static over "mesh") are
    # decorator-form module-level jits and auto-register; they wrap the two
    # _run_fleet/_fleet_step_masked bodies listed above via shard_map.
}

#: Parameter names that are static *by repo convention* wherever they appear
#: in jit-reachable code (frozen config pytree aux data, model/backend
#: selectors, compile-time tile sizes).  TC002/TC003 trust this naming
#: discipline — a traced value must not be bound to one of these names.
STATIC_PARAM_NAMES: frozenset[str] = frozenset({
    "self", "cls", "cfg", "config", "spec", "mesh", "model", "backend",
    "interpret", "precision", "mode", "dtype", "axis", "name", "kind",
    "max_hosts", "max_backfill", "max_starts_per_bin", "t_bins",
    "tb_t", "tb_c", "dt_seconds", "num_hosts", "history_windows",
    "chunk", "use_pallas", "donate", "shard",
    # SLO spec tuples: static structure (thresholds/comparisons picked at
    # trace time), only the observation stream is traced
    "slos",
})

#: Module-level donating jits (dotted path -> donated positional indices)
#: that TC004 tracks *in addition to* the ``jax.jit(..., donate_argnums=…)``
#: assignments it discovers syntactically.  Discovery covers everything in
#: this repo today; the explicit seeds keep the contract stable if a
#: donating jit is ever constructed through a helper the scanner cannot see.
DONATING_JITS: dict[str, tuple[int, ...]] = {
    "repro.core.state.twin_step_jit": (0,),
    "repro.core.twin._run_fleet_jit": (0,),
    "repro.core.twin._fleet_step_masked_jit": (0,),
    "repro.core.scenarios._run_scenarios_jit_donated": (0,),
}

#: Files allowed to cast to bfloat16 (TC005).  The precision policy
#: (PR 7, golden-pinned by tests/golden/readout_bf16.npz): bf16 is legal
#: exactly on the derived performance leaves (tflops/efficiency) inside the
#: fused readout — sustainability math stays f32 everywhere.
BF16_ALLOWED_FILES: frozenset[str] = frozenset({
    "src/repro/kernels/des_readout.py",
})

#: Heavy/non-vendored packages that must never be imported bare (TC006):
#: ROADMAP "optional-dependency policy" — try-import with stdlib fallback,
#: or ``pytest.importorskip`` in tests.  CI runs without them installed.
OPTIONAL_MODULES: tuple[str, ...] = ("zstandard", "hypothesis")

#: Directories (repo-relative prefixes) where TC007 forbids ambient
#: nondeterminism: the deterministic heart of the twin.  ``runtime/`` is
#: included because it produces the traced failure schedules and mesh plans
#: that what-if results (and their goldens) depend on.  ``serve/`` is the
#: streaming service loop: time is injected (Clock), producers are seeded —
#: an ambient clock there would break replay determinism silently.
DETERMINISTIC_DIRS: tuple[str, ...] = (
    "src/repro/core/", "src/repro/kernels/", "src/repro/runtime/",
    "src/repro/serve/")

#: (file, source) pairs TC007 tolerates — the I/O-shell allow-list.
#: Empty today: the orchestrator's wall-clock pacing goes through its
#: injectable Clock (references, not calls, so TC007 stays quiet), and
#: platform-dispatch sites carry inline suppressions with reasons.  Add a
#: pair here only when a whole file/source combination is intended.
NONDETERMINISM_ALLOWED: frozenset[tuple[str, str]] = frozenset()

#: Directories TC001 (no jit construction in function/loop bodies) scans.
#: tests/ is exempt by design: a per-test jit dies with the process, and
#: tests deliberately build throwaway jits to probe retrace behavior.
JIT_HYGIENE_DIRS: tuple[str, ...] = ("src/", "benchmarks/")

#: hypothesis example budget above which a test must be marked ``slow``
#: (pytest.ini runs tier 1 with ``-m "not slow"``; see ROADMAP test tiers).
MAX_FAST_EXAMPLES: int = 50

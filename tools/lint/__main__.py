"""CLI for tracecheck: ``python -m tools.lint [paths...]``.

Exit codes: 0 clean (baselined debt is reported but passes), 1 new
findings or stale baseline entries, 2 usage errors.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

from tools.lint.engine import (
    DEFAULT_BASELINE,
    REPO_ROOT,
    load_baseline,
    run_lint,
)
from tools.lint.rules import EXPLAIN

DEFAULT_PATHS = ("src", "tests", "benchmarks", "tools")


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.lint",
        description="tracecheck: static enforcement of the twin's JAX "
                    "contracts (TC001–TC008).")
    ap.add_argument("paths", nargs="*", default=list(DEFAULT_PATHS),
                    help="files or directories to lint "
                         f"(default: {' '.join(DEFAULT_PATHS)})")
    ap.add_argument("--explain", metavar="RULE",
                    help="print the documentation for one rule and exit")
    ap.add_argument("--baseline", default=str(DEFAULT_BASELINE),
                    help="baseline.json path (default: committed ratchet)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline: every finding fails")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite the baseline from the current findings "
                         "(each entry still needs a hand-written reason)")
    ap.add_argument("--root", default=None,
                    help="treat this directory as the repo root "
                         "(default: the real repo; used for fixture trees)")
    args = ap.parse_args(argv)

    if args.explain:
        rule = args.explain.upper()
        if rule not in EXPLAIN:
            print(f"unknown rule {args.explain!r}; known: "
                  f"{', '.join(sorted(EXPLAIN))}", file=sys.stderr)
            return 2
        print(EXPLAIN[rule].rstrip())
        return 0

    entries: list[dict] = []
    if not args.no_baseline:
        bp = pathlib.Path(args.baseline)
        if bp.exists():
            try:
                entries = load_baseline(bp)
            except ValueError as exc:
                print(f"tracecheck: invalid baseline: {exc}", file=sys.stderr)
                return 2

    result = run_lint(args.paths,
                      root=pathlib.Path(args.root) if args.root else None,
                      baseline_entries=entries)

    if args.write_baseline:
        bp = pathlib.Path(args.baseline)
        existing = {e["key"]: e for e in entries}
        out = {"version": 1, "entries": [
            {"key": f.key,
             "reason": existing.get(f.key, {}).get(
                 "reason", "TODO: justify or fix")}
            for f in result.findings]}
        bp.write_text(json.dumps(out, indent=2) + "\n")
        print(f"tracecheck: wrote {len(out['entries'])} entries to {bp}")
        return 0

    for f in result.new:
        print(f.render())
    for f in result.baselined:
        print(f"{f.render()}  [baselined]")
    for key in result.stale:
        print(f"tracecheck: stale baseline entry (fix shipped — delete "
              f"it): {key}")

    if result.new or result.stale:
        print(f"tracecheck: FAIL — {len(result.new)} new finding(s), "
              f"{len(result.stale)} stale baseline entr(y/ies)",
              file=sys.stderr)
        return 1
    print(f"tracecheck: OK — 0 new findings"
          f"{f', {len(result.baselined)} baselined' if result.baselined else ''}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

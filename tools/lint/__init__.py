"""tracecheck — the repo's JAX contract linter.

``python -m tools.lint src tests benchmarks tools`` statically enforces
the standing invariants (ROADMAP): single-compile jit hygiene, no
concretization/branching on traced values, donated-carry discipline, the
bf16 precision policy, the optional-dependency policy, core determinism,
and test-tier markers.  See ``python -m tools.lint --explain TC001``.
"""

from tools.lint.engine import (  # noqa: F401
    DEFAULT_BASELINE,
    Finding,
    LintResult,
    Project,
    SourceFile,
    assign_keys,
    load_baseline,
    run_lint,
)
from tools.lint.rules import ALL_RULES, EXPLAIN  # noqa: F401

"""The eight tracecheck rules (TC001–TC008).

Each rule is a function ``rule(project) -> list[Finding]``.  The module
also carries :data:`EXPLAIN` — the ``--explain`` text, which doubles as
the rule documentation linked from docs/ARCHITECTURE.md.
"""

from __future__ import annotations

import ast

from tools.lint.engine import (
    Finding,
    Project,
    SourceFile,
    dotted,
    is_jax_jit,
    jit_call_info,
)

EXPLAIN: dict[str, str] = {
    "TC001": """\
TC001 — no jit construction inside function or loop bodies.

`jax.jit(...)` / `functools.partial(jax.jit, ...)` evaluated inside a
function body builds a FRESH compilation cache on every call: the program
recompiles each time, silently turning a microseconds hot path into a
seconds one (the single-compile guarantee in ROADMAP "Standing
invariants").  Jits must be module-level (`_run_scenarios_jit =
jax.jit(_run_scenarios_body, ...)`) or built inside a
`functools.lru_cache`/`functools.cache`-decorated factory, which gives
each distinct configuration exactly one cache.

Scope: src/ and benchmarks/.  tests/ are exempt: a per-test jit dies with
the process, and tests deliberately build throwaway jits to probe retrace
behavior.  Benchmarks that *measure* cold compiles suppress the rule
inline with a reason.

Fix: hoist the jit to module level, or wrap the constructing factory in
`functools.lru_cache`.
""",
    "TC002": """\
TC002 — no concretization of traced values in jit-reachable code.

`float(x)`, `int(x)`, `bool(x)`, `x.item()`, `x.tolist()` and
`np.asarray(x)` force a traced value onto the host.  Under `jax.jit` they
raise `TracerConversionError` at best; under `vmap`/`scan` composition
they can silently constant-fold a value that should vary per lane.  The
jit entry points and their static parameters are declared in
tools/lint/entrypoints.py (JIT_ENTRYPOINTS + auto-discovered
`jax.jit(...)` sites); every function reachable from an entry point is
checked, and every non-static parameter of such a function is treated as
traced.  Shape/dtype access (`x.shape`, `x.ndim`, `x.dtype`) is static
metadata and never flagged; `jnp.asarray` stays on device and is fine.

Limitation (by design): only *parameters* are tracked, not locals derived
from them — the contract is enforced at function boundaries, where review
happens.

Fix: keep the math in jnp (`jnp.asarray`, `jnp.where`), or declare the
parameter static in the entry registry if it genuinely is.
""",
    "TC003": """\
TC003 — no Python `if`/`while` on traced values.

Python control flow on a traced value concretizes it (see TC002) — under
jit it raises, and in the batched scenario engine it would fork the
single compiled program per lane, breaking the single-compile guarantee
for (caps x shifts x policies x topologies) grids.  Branchless
alternatives: `jnp.where`, `lax.cond`, `lax.select`, score-table gathers
(the PR-2 policy kernel pattern).

Presence checks (`x is None` / `x is not None`) are structural — they
pick the compiled program, not a traced branch — and are never flagged;
neither are `isinstance(...)`, `len(...)` or `.shape`/`.ndim`/`.dtype`
tests.  Parameters follow the same traced/static classification as TC002.

Fix: rewrite the branch with `jnp.where`/`lax.cond`, or declare the
parameter static in tools/lint/entrypoints.py.
""",
    "TC004": """\
TC004 — a buffer passed to a donating jit must not be read afterwards.

`jax.jit(fn, donate_argnums=...)` invalidates the donated argument's
buffers: XLA reuses them for the output.  Reading the old reference
afterwards raises `RuntimeError: Array has been deleted` — but only at
runtime, and only on platforms where donation is honored, which is how
the PR-7 optimizer bug shipped (fixed by the host-snapshot pattern:
`jax.tree.map(np.asarray, x)` *before* the donating call).

The donating jits are auto-discovered from `jax.jit(...,
donate_argnums=...)` module-level assignments plus the explicit
DONATING_JITS registry.  Safe patterns: rebind the name in the same
statement (`state, out = twin_step_jit(state, ...)`) or never touch the
old reference again.  Flagged patterns: reading the variable after the
call, or passing it un-rebound from inside a loop (the second iteration
reads a donated buffer).

Fix: rebind the carry, or snapshot to host first.
""",
    "TC005": """\
TC005 — bf16 casts only in the allow-listed readout leaves.

The precision policy (PR 7, pinned by tests/golden/readout_bf16.npz):
bfloat16 is permitted exactly where the f64 oracle tolerance allows it —
the derived performance leaves (tflops, efficiency) inside the fused DES
readout.  Sustainability math (power, energy, gCO2, cost) stays f32: a
bf16 ulp on a power sum is megawatt-hours of drift over a fleet-year.
Any `.astype(jnp.bfloat16)`, `astype("bfloat16")` or `jnp.bfloat16`
reference outside BF16_ALLOWED_FILES (tools/lint/entrypoints.py) is
flagged.  Model *configs* naming "bfloat16" as a dtype string for the
training stack are not casts and are not flagged.

Fix: keep the cast inside src/repro/kernels/des_readout.py behind the
`precision="bf16"` knob, or extend the allow-list in review.
""",
    "TC006": """\
TC006 — optional dependencies are imported guarded, never bare.

ROADMAP "Optional-dependency policy": heavy/non-vendored packages
(zstandard, hypothesis) are try-imported with a stdlib fallback
(repro/core/codec.py) or gated by `pytest.importorskip`; CI runs without
them installed, so one bare import breaks collection everywhere — the
seed suite died exactly this way (6 collection errors, fixed in PR 1).

Allowed forms: `import zstandard` inside a `try:` block, or any import
lexically after a `pytest.importorskip("zstandard")` call in the same
file (module-level or inside the function).

Fix: wrap in try/except ImportError with a fallback, or importorskip.
""",
    "TC007": """\
TC007 — no ambient nondeterminism in the deterministic core.

src/repro/core/, src/repro/kernels/ and src/repro/runtime/ are the
bit-for-bit heart of the twin: goldens, the oracle cross-check and the
scenario cache keys all assume that the same inputs give the same
outputs.  Calls to wall clocks (`time.time`, `time.monotonic`, ...),
ambient RNGs (`np.random.*` unseeded, stdlib `random`), `uuid4`,
`os.urandom` and ambient device discovery (`jax.devices()` as a hidden
default) smuggle environment state into that core.

*References* are fine — `clock: Callable = time.time` as an injectable
default is the sanctioned pattern (the orchestrator's Clock); only calls
are flagged.  `np.random.default_rng(seed)` with an explicit seed is
deterministic and allowed; `jax.random.*` is always keyed and never
flagged.  The I/O-shell allow-list (NONDETERMINISM_ALLOWED) covers
orchestrator pacing (`time.sleep` — wall-clock pacing is its job, paper
section 2.3); platform-dispatch sites suppress inline with a reason.

Fix: inject the clock/rng/devices from the caller.
""",
    "TC008": """\
TC008 — heavy test loops carry the `slow` marker.

pytest.ini runs tier 1 with `-m "not slow"`; heavy tests belong to the
tier2-slow CI job (ROADMAP test tiers).  Flagged: hypothesis
`@settings(max_examples=N)` with N > 50 on a test without
`@pytest.mark.slow` (module-level `pytestmark` counts), and golden-file
writes (`np.savez*` into tests/golden) from unmarked test functions —
golden regeneration belongs in tools/capture_*.py scripts, not in the
fast tier.

Fix: mark the test `slow`, shrink the example budget, or move the regen
into a tools/ script.
""",
}

_CONCRETIZERS = {"float", "int", "bool", "complex"}
_CONCRETIZE_FUNCS = {"np.asarray", "np.array", "numpy.asarray", "numpy.array"}
_CONCRETIZE_METHODS = {"item", "tolist"}
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size"}

_NONDET_CALLS = {
    "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns", "time.sleep",
    "datetime.datetime.now", "datetime.datetime.utcnow", "datetime.now",
    "os.urandom", "uuid.uuid1", "uuid.uuid4", "secrets.token_bytes",
    "secrets.token_hex", "jax.devices", "jax.local_devices",
}


def _in_scope(sf: SourceFile, prefixes) -> bool:
    return any(sf.path.startswith(p) for p in prefixes)


# -- TC001 --------------------------------------------------------------------

def _cached_factory(sf: SourceFile, fn: ast.AST) -> bool:
    """Is this function decorated with functools.lru_cache / cache?"""
    for dec in fn.decorator_list:  # type: ignore[union-attr]
        target = dec.func if isinstance(dec, ast.Call) else dec
        if dotted(target) in ("functools.lru_cache", "functools.cache",
                              "lru_cache", "cache"):
            return True
    return False


def rule_tc001(project: Project) -> list[Finding]:
    out: list[Finding] = []
    for sf in project.files:
        if not _in_scope(sf, project.registry.JIT_HYGIENE_DIRS):
            continue
        for node in ast.walk(sf.tree):
            jit_site = None
            if isinstance(node, ast.Call):
                info = jit_call_info(node, sf)
                if info is not None:
                    jit_site = node
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                # bare @jax.jit decorator on a *nested* function
                for dec in node.decorator_list:
                    if not isinstance(dec, ast.Call) and is_jax_jit(dec, sf):
                        if sf.enclosing_function(node) is not None:
                            jit_site = dec
            if jit_site is None:
                continue
            enc = sf.enclosing_function(jit_site)
            if enc is None:
                continue                      # module level: fine
            if jit_site in getattr(enc, "decorator_list", []) \
                    and sf.enclosing_function(enc) is None:
                continue                      # decorator of a top-level def
            # allowed inside an lru_cache'd factory anywhere up the chain
            cur = enc
            cached = False
            while cur is not None:
                if _cached_factory(sf, cur):
                    cached = True
                    break
                cur = sf.enclosing_function(cur)
            if cached:
                continue
            out.append(Finding(
                "TC001", sf.path, jit_site.lineno,
                f"jax.jit constructed inside '{enc.name}' — a fresh "
                "compilation cache per call (recompile hazard); hoist to "
                "module level or an lru_cache'd factory"))
    return out


# -- TC002 / TC003 ------------------------------------------------------------

def _traced_name_of(expr: ast.AST, traced: set[str]) -> str | None:
    """Name of the traced parameter an expression is rooted at, if any.

    Walks down Attribute/Subscript chains; chains touching static metadata
    (`.shape`, `.ndim`, `.dtype`, `.size`) are never traced.
    """
    cur = expr
    while True:
        if isinstance(cur, ast.Attribute):
            if cur.attr in _STATIC_ATTRS:
                return None
            cur = cur.value
        elif isinstance(cur, ast.Subscript):
            cur = cur.value
        else:
            break
    if isinstance(cur, ast.Name) and cur.id in traced:
        return cur.id
    return None


def _function_defs(fi_node: ast.AST):
    """(def, params-of-def) for the function and every nested def inside."""
    for node in ast.walk(fi_node):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            yield node


def _traced_params_for_def(project: Project, fi, node) -> set[str]:
    if node is fi.node:
        return project.traced_params(fi)
    # nested def / lambda inside a reachable function: its params are traced
    # too (vmapped lane bodies, scan bodies) minus the conventional statics
    a = node.args
    out = set()
    for p in a.posonlyargs + a.args + a.kwonlyargs:
        if p.arg in project.registry.STATIC_PARAM_NAMES or p.arg in fi.statics:
            continue
        if not isinstance(node, ast.Lambda) and Project._static_annotation(p):
            continue
        out.add(p.arg)
    return out


def _owning_def(sf: SourceFile, node: ast.AST, fi) -> ast.AST | None:
    """Nearest def/lambda ancestor of node that is within fi.node."""
    cur = getattr(node, "_tc_parent", None)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            return cur
        if cur is fi.node:
            return fi.node
        cur = getattr(cur, "_tc_parent", None)
    return None


def rule_tc002(project: Project) -> list[Finding]:
    out: list[Finding] = []
    for q, fi in sorted(project.reachable.items()):
        sf = fi.sf
        for node in ast.walk(fi.node):
            if not isinstance(node, ast.Call):
                continue
            owner = _owning_def(sf, node, fi)
            if owner is None:
                continue
            traced = _traced_params_for_def(project, fi, owner)
            target: ast.AST | None = None
            what = None
            fname = dotted(node.func)
            if isinstance(node.func, ast.Name) \
                    and node.func.id in _CONCRETIZERS and len(node.args) == 1:
                target, what = node.args[0], f"{node.func.id}()"
            elif fname is not None and (
                    fname in _CONCRETIZE_FUNCS
                    or project.resolve_call(sf, node) in _CONCRETIZE_FUNCS):
                if node.args:
                    target, what = node.args[0], f"{fname}()"
            elif isinstance(node.func, ast.Attribute) \
                    and node.func.attr in _CONCRETIZE_METHODS \
                    and not node.args:
                target, what = node.func.value, f".{node.func.attr}()"
            if target is None:
                continue
            name = _traced_name_of(target, traced)
            if name is None:
                continue
            out.append(Finding(
                "TC002", sf.path, node.lineno,
                f"{what} concretizes traced parameter '{name}' in "
                f"'{q.rsplit('.', 1)[-1]}' (jit-reachable); keep it in jnp "
                "or declare the parameter static in the entry registry"))
    return out


class _BranchNames(ast.NodeVisitor):
    """Collect Names in a branch test, skipping structural checks."""

    def __init__(self):
        self.names: list[ast.Name] = []

    def visit_Compare(self, node: ast.Compare):
        if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
            return                      # presence check: structural
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call):
        if isinstance(node.func, ast.Name) \
                and node.func.id in ("isinstance", "hasattr", "len",
                                     "callable", "getattr"):
            return                      # structural predicates
        self.generic_visit(node)

    def visit_Attribute(self, node: ast.Attribute):
        if node.attr in _STATIC_ATTRS:
            return                      # x.shape / x.ndim / x.dtype: static
        self.generic_visit(node)

    def visit_Name(self, node: ast.Name):
        self.names.append(node)


def rule_tc003(project: Project) -> list[Finding]:
    out: list[Finding] = []
    for q, fi in sorted(project.reachable.items()):
        sf = fi.sf
        for node in ast.walk(fi.node):
            if not isinstance(node, (ast.If, ast.While, ast.IfExp)):
                continue
            owner = _owning_def(sf, node, fi)
            if owner is None:
                continue
            traced = _traced_params_for_def(project, fi, owner)
            v = _BranchNames()
            v.visit(node.test)
            hits = sorted({n.id for n in v.names if n.id in traced})
            if not hits:
                continue
            kind = {ast.If: "if", ast.While: "while",
                    ast.IfExp: "conditional expression"}[type(node)]
            out.append(Finding(
                "TC003", sf.path, node.lineno,
                f"Python {kind} on traced parameter(s) "
                f"{', '.join(repr(h) for h in hits)} in "
                f"'{q.rsplit('.', 1)[-1]}' (jit-reachable); use "
                "jnp.where/lax.cond or declare the parameter static"))
    return out


# -- TC004 --------------------------------------------------------------------

def _stmt_of(node: ast.AST):
    cur = node
    while cur is not None and not isinstance(cur, ast.stmt):
        cur = getattr(cur, "_tc_parent", None)
    return cur


def _assign_targets(stmt: ast.stmt) -> set[str]:
    names: set[str] = set()
    targets = []
    if isinstance(stmt, ast.Assign):
        targets = stmt.targets
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        targets = [stmt.target]
    for t in targets:
        stack = [t]
        while stack:
            n = stack.pop()
            if isinstance(n, (ast.Tuple, ast.List)):
                stack.extend(n.elts)
            else:
                d = dotted(n)
                if d:
                    names.add(d)
    return names


def rule_tc004(project: Project) -> list[Finding]:
    out: list[Finding] = []
    for sf in project.files:
        for fn in ast.walk(sf.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            for call in ast.walk(fn):
                if not isinstance(call, ast.Call):
                    continue
                target = project.resolve_call(sf, call)
                donate = project.donating.get(target or "")
                if not donate:
                    continue
                stmt = _stmt_of(call)
                if stmt is None:
                    continue
                rebound = _assign_targets(stmt)
                for pos in donate:
                    if pos >= len(call.args):
                        continue
                    argname = dotted(call.args[pos])
                    if argname is None:
                        continue        # expression arg: nothing to re-read
                    if argname in rebound:
                        continue        # state, out = f(state, ...): safe
                    # un-rebound donation inside a loop: next iteration
                    # passes (= reads) the donated buffer again
                    in_loop = any(isinstance(a, (ast.For, ast.While))
                                  for a in sf.ancestors(stmt)
                                  if sf.enclosing_function(a) is
                                  sf.enclosing_function(stmt))
                    reused_line = None
                    if in_loop:
                        reused_line = call.lineno
                    else:
                        end = stmt.end_lineno or stmt.lineno
                        events = []
                        for n in ast.walk(fn):
                            line = getattr(n, "lineno", None)
                            if line is None or line <= end:
                                continue
                            if isinstance(n, (ast.Name, ast.Attribute)) \
                                    and dotted(n) == argname:
                                is_store = isinstance(
                                    getattr(n, "ctx", None), ast.Store)
                                events.append(
                                    (line, n.col_offset, is_store))
                        if events:
                            # first touch after the call: a read means the
                            # donated buffer is used; a store re-binds it
                            _, _, first_is_store = min(events)
                            if not first_is_store:
                                reused_line = min(events)[0]
                    if reused_line is None:
                        continue
                    out.append(Finding(
                        "TC004", sf.path, call.lineno,
                        f"'{argname}' is donated to "
                        f"{(target or '?').rsplit('.', 1)[-1]} (arg {pos}) "
                        "and read again afterwards — the buffer is "
                        "invalidated by donation; rebind it from the "
                        "result or snapshot to host first"))
    return out


# -- TC005 --------------------------------------------------------------------

def rule_tc005(project: Project) -> list[Finding]:
    out: list[Finding] = []
    for sf in project.files:
        if sf.path in project.registry.BF16_ALLOWED_FILES:
            continue
        for node in ast.walk(sf.tree):
            line = None
            if isinstance(node, ast.Attribute) and node.attr == "bfloat16":
                line = node.lineno
            elif isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "astype" and node.args \
                    and isinstance(node.args[0], ast.Constant) \
                    and node.args[0].value in ("bfloat16", "bf16"):
                line = node.lineno
            if line is None:
                continue
            out.append(Finding(
                "TC005", sf.path, line,
                "bfloat16 cast outside the precision-policy allow-list — "
                "bf16 is legal only on the tflops/efficiency leaves in "
                "src/repro/kernels/des_readout.py (golden-pinned)"))
    return out


# -- TC006 --------------------------------------------------------------------

def rule_tc006(project: Project) -> list[Finding]:
    out: list[Finding] = []
    optional = project.registry.OPTIONAL_MODULES
    for sf in project.files:
        skip_lines = [n.lineno for n in ast.walk(sf.tree)
                      if isinstance(n, ast.Call)
                      and dotted(n.func) == "pytest.importorskip"
                      and n.args and isinstance(n.args[0], ast.Constant)]
        for node in ast.walk(sf.tree):
            mods: list[str] = []
            if isinstance(node, ast.Import):
                mods = [a.name.split(".")[0] for a in node.names]
            elif isinstance(node, ast.ImportFrom) and node.module:
                mods = [node.module.split(".")[0]]
            hits = [m for m in mods if m in optional]
            if not hits:
                continue
            if any(isinstance(a, ast.Try) for a in sf.ancestors(node)):
                continue
            if any(line < node.lineno for line in skip_lines):
                continue
            out.append(Finding(
                "TC006", sf.path, node.lineno,
                f"bare import of optional dependency "
                f"{'/'.join(sorted(set(hits)))} — CI runs without it; "
                "try-import with a stdlib fallback or pytest.importorskip "
                "(ROADMAP optional-dependency policy)"))
    return out


# -- TC007 --------------------------------------------------------------------

def rule_tc007(project: Project) -> list[Finding]:
    out: list[Finding] = []
    allowed = project.registry.NONDETERMINISM_ALLOWED
    for sf in project.files:
        if not _in_scope(sf, project.registry.DETERMINISTIC_DIRS):
            continue
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            d = project.resolve_call(sf, node) or ""
            src = None
            if d in _NONDET_CALLS:
                src = d
            elif d.startswith(("numpy.random.", "np.random.")):
                if d.rsplit(".", 1)[-1] == "default_rng" and node.args:
                    src = None          # explicitly seeded: deterministic
                else:
                    src = d
            elif d.startswith("random.") or d == "random":
                src = d
            if src is None:
                continue
            short = src.replace("numpy.", "np.")
            if (sf.path, short) in allowed or (sf.path, src) in allowed:
                continue
            out.append(Finding(
                "TC007", sf.path, node.lineno,
                f"nondeterminism source {short}() called in the "
                "deterministic core — inject it (clock/rng/devices "
                "parameter) or add an allow-list entry with a reason"))
    return out


# -- TC008 --------------------------------------------------------------------

def _has_slow_marker(sf: SourceFile, fn: ast.AST) -> bool:
    for dec in fn.decorator_list:  # type: ignore[union-attr]
        d = dotted(dec.func if isinstance(dec, ast.Call) else dec)
        if d in ("pytest.mark.slow", "mark.slow"):
            return True
    for stmt in sf.tree.body:
        if isinstance(stmt, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == "pytestmark"
                for t in stmt.targets):
            for n in ast.walk(stmt.value):
                if dotted(n) in ("pytest.mark.slow", "mark.slow"):
                    return True
    return False


def _max_examples_of(sf: SourceFile, dec: ast.Call) -> int | None:
    kwargs = list(dec.keywords)
    for kw in list(kwargs):
        if kw.arg is None and isinstance(kw.value, ast.Name):
            # @settings(**SETTINGS): resolve the module-level dict(...)
            for stmt in sf.tree.body:
                if isinstance(stmt, ast.Assign) and any(
                        isinstance(t, ast.Name) and t.id == kw.value.id
                        for t in stmt.targets) \
                        and isinstance(stmt.value, ast.Call):
                    kwargs.extend(stmt.value.keywords)
    for kw in kwargs:
        if kw.arg == "max_examples" and isinstance(kw.value, ast.Constant) \
                and isinstance(kw.value.value, int):
            return kw.value.value
    return None


def rule_tc008(project: Project) -> list[Finding]:
    out: list[Finding] = []
    budget = project.registry.MAX_FAST_EXAMPLES
    for sf in project.files:
        if not sf.path.startswith("tests/"):
            continue
        for fn in ast.walk(sf.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            slow = _has_slow_marker(sf, fn)
            if slow:
                continue
            for dec in fn.decorator_list:
                if isinstance(dec, ast.Call) \
                        and dotted(dec.func) == "settings":
                    n = _max_examples_of(sf, dec)
                    if n is not None and n > budget:
                        out.append(Finding(
                            "TC008", sf.path, dec.lineno,
                            f"hypothesis max_examples={n} > {budget} "
                            f"on unmarked '{fn.name}' — mark it "
                            "@pytest.mark.slow or shrink the budget "
                            "(tier-1 runs -m 'not slow')"))
            if fn.name.startswith("test_"):
                for node in ast.walk(fn):
                    if isinstance(node, ast.Call) and (
                            dotted(node.func) or "").endswith(
                            ("np.savez", "np.savez_compressed",
                             "numpy.savez", "numpy.savez_compressed")):
                        out.append(Finding(
                            "TC008", sf.path, node.lineno,
                            f"golden write (savez) inside unmarked "
                            f"'{fn.name}' — golden regeneration belongs "
                            "in tools/capture_*.py, not the fast tier"))
    return out


ALL_RULES = (rule_tc001, rule_tc002, rule_tc003, rule_tc004,
             rule_tc005, rule_tc006, rule_tc007, rule_tc008)
